//! The paper's §5.1 empirical design-space search: sweep GPU splits and
//! per-phase power allocations under the 4800 W budget on LongBench, and
//! report the best static configuration ("We shifted GPUs between prefill
//! and decode by increments of one, and shifted power by 50 W … to
//! identify 4P-750W/4D-450W as the optimal configuration").
//!
//! Run: `cargo run --release --example power_sweep [-- <qps_per_gpu>]`

use rapid::config::{presets, Topology};
use rapid::experiments::longbench_trace;
use rapid::sim::{self, SimOptions};
use rapid::types::Slo;

fn main() {
    let qps: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.5);
    let n = 1000;
    let seed = 42;
    println!("static design-space sweep @{qps} QPS/GPU, 4800 W node budget\n");
    println!(
        "{:<10}{:>10}{:>10}{:>13}{:>10}{:>10}",
        "split", "prefill W", "decode W", "attainment", "goodput", "qps/kW"
    );
    let mut best: Option<(String, f64, f64)> = None;
    for p in 2..=6usize {
        let d = 8 - p;
        let mut pw = 400.0;
        while pw <= 750.0 + 1e-9 {
            let dw = (4800.0 - pw * p as f64) / d as f64;
            if (400.0..=750.0).contains(&dw) {
                let mut cfg = presets::p4d4(600.0);
                cfg.name = format!("{p}P-{pw:.0}W/{d}D-{dw:.0}W");
                cfg.topology = Topology::Disaggregated {
                    prefill: p,
                    decode: d,
                };
                cfg.prefill_cap_w = pw;
                cfg.decode_cap_w = dw;
                if cfg.validate().is_ok() {
                    let trace = longbench_trace(seed, qps * 8.0, n, Slo::paper_default());
                    let res = sim::run(&cfg, &trace, &SimOptions::default());
                    println!(
                        "{:<10}{:>10.0}{:>10.0}{:>12.1}%{:>10.2}{:>10.3}",
                        format!("{p}P{d}D"),
                        pw,
                        dw,
                        res.attainment() * 100.0,
                        res.goodput_qps(),
                        res.qps_per_kw()
                    );
                    let score = res.attainment();
                    if best.as_ref().map_or(true, |&(_, s, _)| score > s) {
                        best = Some((cfg.name.clone(), score, res.goodput_qps()));
                    }
                }
            }
            pw += 50.0;
        }
    }
    if let Some((name, att, gp)) = best {
        println!(
            "\nbest static configuration: {name} (attainment {:.1}%, goodput {gp:.2} qps)",
            att * 100.0
        );
        println!("paper's answer at this operating point: 4P-750W/4D-450W");
    }
}
