//! Per-GPU simulated worker state (prefill / decode / coalesced).

use std::collections::VecDeque;

use crate::coordinator::batcher::ChunkProgress;
use crate::sim::event::DecodeItem;
use crate::types::{Micros, Request, Role};

/// Chunked-prefill bookkeeping on a coalesced GPU.
#[derive(Debug, Clone)]
pub struct ChunkMeta {
    pub prog: ChunkProgress,
    /// When the first chunk of this prompt began executing.
    pub started: Option<Micros>,
}

/// One simulated GPU worker.
#[derive(Debug)]
pub struct GpuSim {
    pub role: Role,
    /// Set while the GPU drains toward a new role.
    pub draining_to: Option<Role>,
    /// Bumped on every role change; in-flight events with an older epoch
    /// are stale and ignored.
    pub epoch: u64,
    /// An execution (prefill batch / decode step / coalesced step) is in
    /// flight.
    pub busy: bool,
    /// Down due to an environment `GpuFail`: accepts nothing, draws
    /// nothing, counts for nothing until `GpuRecover`.
    pub failed: bool,

    // --- prefill ---
    pub pf_queue: VecDeque<Request>,
    pub pf_queued_tokens: u64,
    /// In-flight prefill batch: (request, prefill_start).
    pub pf_batch: Vec<(Request, Micros)>,
    /// Completed prefills waiting for a free ring slot (backpressure).
    pub publish_wait: VecDeque<DecodeItem>,

    // --- decode ---
    pub dec_pending: VecDeque<DecodeItem>,
    pub dec_active: Vec<DecodeItem>,
    /// Duration of the decode step currently in flight.
    pub dec_step_time: Micros,

    // --- coalesced ---
    pub co_queue: VecDeque<ChunkMeta>,
    /// Prompts completing in the in-flight coalesced step.
    pub co_finishing: Vec<(Request, Micros)>,
    /// Chunk tokens being processed in the in-flight step.
    pub co_step_chunk: u32,
}

impl GpuSim {
    pub fn new(role: Role) -> Self {
        GpuSim {
            role,
            draining_to: None,
            epoch: 0,
            busy: false,
            failed: false,
            pf_queue: VecDeque::new(),
            pf_queued_tokens: 0,
            pf_batch: Vec::new(),
            publish_wait: VecDeque::new(),
            dec_pending: VecDeque::new(),
            dec_active: Vec::new(),
            dec_step_time: 0,
            co_queue: VecDeque::new(),
            co_finishing: Vec::new(),
            co_step_chunk: 0,
        }
    }

    /// The role this GPU is committed to (target role while draining).
    pub fn committed_role(&self) -> Role {
        self.draining_to.unwrap_or(self.role)
    }

    /// May the router send new work here?
    pub fn accepting(&self) -> bool {
        self.draining_to.is_none() && !self.failed
    }

    pub fn push_prefill(&mut self, r: Request) {
        self.pf_queued_tokens += r.input_tokens as u64;
        self.pf_queue.push_back(r);
    }

    pub fn pop_prefill_tokens(&mut self, tokens: u64) {
        self.pf_queued_tokens -= tokens;
    }

    /// Decode occupancy: resident + pending requests.
    pub fn decode_load(&self) -> usize {
        self.dec_active.len() + self.dec_pending.len()
    }

    /// Mean live context across active decode requests.
    pub fn mean_ctx(&self) -> f64 {
        if self.dec_active.is_empty() {
            return 0.0;
        }
        self.dec_active.iter().map(|d| d.ctx_tokens() as f64).sum::<f64>()
            / self.dec_active.len() as f64
    }

    /// Queued coalesced prompt tokens remaining.
    pub fn co_queued_tokens(&self) -> u64 {
        self.co_queue.iter().map(|c| c.prog.remaining() as u64).sum()
    }

    /// Has this GPU fully drained (safe to flip roles)?
    pub fn drained(&self) -> bool {
        !self.busy
            && self.pf_queue.is_empty()
            && self.pf_batch.is_empty()
            && self.publish_wait.is_empty()
            && self.dec_pending.is_empty()
            && self.dec_active.is_empty()
            && self.co_queue.is_empty()
            && self.co_finishing.is_empty()
    }

    /// Utilization estimate for the power-draw model.
    pub fn util(&self) -> f64 {
        if !self.busy {
            return 0.0;
        }
        match self.role {
            Role::Prefill | Role::Coalesced => 1.0,
            Role::Decode => {
                // Memory-bound: utilization grows with batch occupancy.
                0.35 + 0.65 * (self.dec_active.len() as f64 / 24.0).min(1.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{RequestId, Slo};

    fn req(id: u64, input: u32) -> Request {
        Request {
            id: RequestId(id),
            arrival: 0,
            input_tokens: input,
            output_tokens: 8,
            slo: Slo::paper_default(),
            tenant: 0,
        }
    }

    #[test]
    fn prefill_token_accounting() {
        let mut g = GpuSim::new(Role::Prefill);
        g.push_prefill(req(0, 1000));
        g.push_prefill(req(1, 500));
        assert_eq!(g.pf_queued_tokens, 1500);
        g.pop_prefill_tokens(1000);
        assert_eq!(g.pf_queued_tokens, 500);
    }

    #[test]
    fn committed_role_reflects_drain_target() {
        let mut g = GpuSim::new(Role::Decode);
        assert_eq!(g.committed_role(), Role::Decode);
        assert!(g.accepting());
        g.draining_to = Some(Role::Prefill);
        assert_eq!(g.committed_role(), Role::Prefill);
        assert!(!g.accepting());
    }

    #[test]
    fn drained_requires_everything_empty() {
        let mut g = GpuSim::new(Role::Decode);
        assert!(g.drained());
        g.dec_active.push(DecodeItem {
            req: req(0, 100),
            prefill_start: 0,
            first_token: 0,
            tokens_done: 1,
            cached_tokens: 0,
        });
        assert!(!g.drained());
        g.dec_active.clear();
        g.busy = true;
        assert!(!g.drained());
    }

    #[test]
    fn util_by_role() {
        let mut g = GpuSim::new(Role::Prefill);
        assert_eq!(g.util(), 0.0);
        g.busy = true;
        assert_eq!(g.util(), 1.0);
        let mut d = GpuSim::new(Role::Decode);
        d.busy = true;
        let low = d.util();
        for i in 0..24 {
            d.dec_active.push(DecodeItem {
                req: req(i, 100),
                prefill_start: 0,
                first_token: 0,
                tokens_done: 1,
                cached_tokens: 0,
            });
        }
        assert!(d.util() > low);
        assert!(d.util() <= 1.0);
    }

    #[test]
    fn mean_ctx_over_active() {
        let mut g = GpuSim::new(Role::Decode);
        assert_eq!(g.mean_ctx(), 0.0);
        for (i, inp) in [(0u64, 100u32), (1, 300)] {
            g.dec_active.push(DecodeItem {
                req: req(i, inp),
                prefill_start: 0,
                first_token: 0,
                tokens_done: 10,
                cached_tokens: 0,
            });
        }
        assert!((g.mean_ctx() - 210.0).abs() < 1e-9); // (110 + 310) / 2
    }
}
