//! Coalesced worker behavior: Sarathi-style chunked prefill co-scheduled
//! with the resident decode batch — the vLLM baseline the paper
//! disaggregates away from.

use std::collections::VecDeque;

use crate::cluster::Cluster;
use crate::coordinator::batcher::{self, ChunkProgress};
use crate::sim::event::{DecodeItem, Event};
use crate::sim::gpu::ChunkMeta;
use crate::sim::worker::RoleBehavior;
use crate::types::{GpuId, Role};

pub struct CoalescedBehavior;

impl RoleBehavior for CoalescedBehavior {
    fn role(&self) -> Role {
        Role::Coalesced
    }

    fn kick(&self, cl: &mut Cluster, gi: usize) {
        cl.kick_coalesced(gi);
    }

    fn on_step_done(&self, cl: &mut Cluster, gi: usize, epoch: u64) {
        cl.on_coalesced_step(gi, epoch);
    }
}

impl Cluster {
    pub(crate) fn kick_coalesced(&mut self, gi: usize) {
        let chunk_budget = self.cfg.perf.chunk_tokens;
        let g = &mut self.gpus[gi];
        if g.busy || g.role != Role::Coalesced {
            return;
        }
        if g.co_queue.is_empty() && g.dec_active.is_empty() && g.dec_pending.is_empty() {
            return;
        }
        // Admit locally-finished prefills (they sit in dec_pending).
        let n = batcher::decode_admissions(
            g.dec_active.len(),
            g.dec_pending.len(),
            &self.cfg.batch,
        );
        for _ in 0..n {
            let item = g.dec_pending.pop_front().unwrap();
            g.dec_active.push(item);
        }
        // Take the next prefill chunk (if any prompt is queued).
        let mut done_before = 0u32;
        if let Some(head) = g.co_queue.front_mut() {
            if head.started.is_none() {
                head.started = Some(self.now);
            }
            done_before = head.prog.done_tokens;
        }
        let mut queue = std::mem::take(&mut g.co_queue);
        // Mark start times for any prompt the chunk reaches.
        let (used, finished_reqs) = {
            let mut progs: VecDeque<ChunkProgress> =
                queue.iter().map(|c| c.prog.clone()).collect();
            let r = batcher::take_chunk(&mut progs, chunk_budget);
            // Write back progress into the metas that remain.
            let consumed = queue.len() - progs.len();
            let finished_meta: Vec<ChunkMeta> = queue.drain(..consumed).collect();
            for (meta, prog) in queue.iter_mut().zip(progs.iter()) {
                meta.prog = prog.clone();
                if meta.prog.done_tokens > 0 && meta.started.is_none() {
                    meta.started = Some(self.now);
                }
            }
            let mut finished = Vec::new();
            for meta in finished_meta {
                finished.push((meta.prog.request.clone(), meta.started.unwrap_or(self.now)));
            }
            (r.0, finished)
        };
        g.co_queue = queue;
        g.co_finishing = finished_reqs;
        g.co_step_chunk = used;
        if used == 0 && g.dec_active.is_empty() {
            return; // nothing to do this iteration
        }
        g.busy = true;
        let batch = g.dec_active.len();
        let ctx = g.mean_ctx();
        let power = self.power.effective(GpuId(gi), self.now);
        let t = self
            .model
            .coalesced_step_time(used, done_before, batch, ctx, power);
        self.gpus[gi].dec_step_time = t;
        let epoch = self.gpus[gi].epoch;
        self.events
            .push(self.now + t, Event::StepDone { gpu: gi, epoch });
    }

    pub(crate) fn on_coalesced_step(&mut self, gi: usize, epoch: u64) {
        if self.gpus[gi].epoch != epoch {
            return;
        }
        let step = self.gpus[gi].dec_step_time;
        self.gpus[gi].busy = false;
        // Prefill completions: first token now; join local decode.
        let finishing = std::mem::take(&mut self.gpus[gi].co_finishing);
        let dynamic = self.policy.is_dynamic();
        for (req, started) in finishing {
            if dynamic {
                let ratio = (self.now - req.arrival) as f64 / req.slo.ttft as f64;
                self.policy.observe_ttft(self.now, ratio);
            }
            if req.output_tokens <= 1 {
                let now = self.now;
                self.push_record(&req, started, now, now);
                continue;
            }
            self.gpus[gi].dec_pending.push_back(DecodeItem {
                req,
                prefill_start: started,
                first_token: self.now,
                tokens_done: 1,
            });
        }
        // Decode completions.
        let mut ratio_sum = 0.0;
        let mut finished: Vec<DecodeItem> = Vec::new();
        let mut tpot_sample = None;
        {
            let g = &mut self.gpus[gi];
            let mut idx = 0;
            while idx < g.dec_active.len() {
                g.dec_active[idx].tokens_done += 1;
                ratio_sum += step as f64 / g.dec_active[idx].req.slo.tpot as f64;
                if g.dec_active[idx].remaining() == 0 {
                    finished.push(g.dec_active.swap_remove(idx));
                } else {
                    idx += 1;
                }
            }
            let n = g.dec_active.len() + finished.len();
            if n > 0 {
                tpot_sample = Some(ratio_sum / n as f64);
            }
        }
        if dynamic {
            if let Some(ratio) = tpot_sample {
                self.policy.observe_tpot(self.now, ratio);
            }
        }
        for item in finished {
            let now = self.now;
            self.push_record(&item.req, item.prefill_start, item.first_token, now);
        }
        self.kick_coalesced(gi);
    }
}
