//! Local per-GPU batching (paper §3.2: "Each worker process has a local
//! scheduler that batches requests based on the GPU's memory capacity").
//!
//! * Prefill: FIFO batch formation under a token budget and a request cap
//!   (vLLM-style: never reorder, fill until a limit trips).
//! * Decode: continuous batching — admissions happen at step boundaries
//!   up to the memory-capacity slot limit.
//! * Coalesced: chunked prefill — one token-budgeted chunk of the head
//!   prompt per iteration, co-scheduled with the resident decode batch.

use std::collections::VecDeque;

use crate::config::BatchConfig;
use crate::types::Request;
use crate::util::slab::SlotId;

/// A formed prefill batch.
#[derive(Debug, Clone, Default)]
pub struct PrefillBatch {
    pub requests: Vec<Request>,
    pub total_tokens: u32,
}

/// Pop a FIFO prefill batch respecting the token and request budgets.
/// Always admits at least one request (a single over-budget prompt must
/// not deadlock the queue).
pub fn form_prefill_batch(queue: &mut VecDeque<Request>, cfg: &BatchConfig) -> PrefillBatch {
    let mut requests = Vec::new();
    let total_tokens = form_prefill_batch_into(queue, cfg, &mut requests);
    PrefillBatch { requests, total_tokens }
}

/// [`form_prefill_batch`] into a caller-owned buffer (cleared first),
/// returning the batch's total prompt tokens — the zero-allocation
/// variant the simulator's per-batch hot path uses with a reused
/// scratch vector.
pub fn form_prefill_batch_into(
    queue: &mut VecDeque<Request>,
    cfg: &BatchConfig,
    out: &mut Vec<Request>,
) -> u32 {
    out.clear();
    let mut total_tokens = 0u32;
    while let Some(front) = queue.front() {
        let would_be = total_tokens + front.input_tokens;
        let fits = out.is_empty()
            || (would_be <= cfg.max_prefill_tokens && out.len() < cfg.max_prefill_reqs);
        if !fits {
            break;
        }
        let r = queue.pop_front().unwrap();
        total_tokens += r.input_tokens;
        out.push(r);
    }
    total_tokens
}

/// Slab-backed variant of [`form_prefill_batch_into`]: the queue holds
/// request-store [`SlotId`]s and `tokens_of` resolves a slot's prompt
/// length. Identical admission rule (FIFO under token + request budgets;
/// a lone over-budget prompt still admits), returning total prompt
/// tokens. This is the simulator's hot path; the `Request` variants
/// above remain for callers that own their requests.
pub fn form_prefill_batch_ids(
    queue: &mut VecDeque<SlotId>,
    cfg: &BatchConfig,
    tokens_of: impl Fn(SlotId) -> u32,
    out: &mut Vec<SlotId>,
) -> u32 {
    out.clear();
    let mut total_tokens = 0u32;
    while let Some(&front) = queue.front() {
        let would_be = total_tokens + tokens_of(front);
        let fits = out.is_empty()
            || (would_be <= cfg.max_prefill_tokens && out.len() < cfg.max_prefill_reqs);
        if !fits {
            break;
        }
        let s = queue.pop_front().unwrap();
        total_tokens += tokens_of(s);
        out.push(s);
    }
    total_tokens
}

/// Decode admission: how many pending requests may join given the current
/// resident count and the slot limit.
pub fn decode_admissions(resident: usize, pending: usize, cfg: &BatchConfig) -> usize {
    cfg.max_decode_reqs.saturating_sub(resident).min(pending)
}

/// Chunked-prefill scheduling state for one prompt on a coalesced GPU.
#[derive(Debug, Clone)]
pub struct ChunkProgress {
    pub request: Request,
    pub done_tokens: u32,
}

impl ChunkProgress {
    pub fn new(request: Request) -> Self {
        ChunkProgress {
            request,
            done_tokens: 0,
        }
    }

    pub fn remaining(&self) -> u32 {
        self.request.input_tokens - self.done_tokens
    }

    /// Advance by up to `budget` tokens; returns tokens consumed.
    pub fn advance(&mut self, budget: u32) -> u32 {
        let step = self.remaining().min(budget);
        self.done_tokens += step;
        step
    }

    pub fn complete(&self) -> bool {
        self.done_tokens >= self.request.input_tokens
    }
}

// NOTE: chunk-taking across queued prompts (head-first, spilling into
// later prompts if the head finishes inside the budget — Sarathi packs
// chunks to the budget) lives in `Cluster::kick_coalesced`, which walks
// the slab-backed slot queue in place; `ChunkProgress` above remains the
// standalone per-prompt bookkeeping unit for callers that own requests.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{RequestId, Slo};

    fn req(id: u64, tokens: u32) -> Request {
        Request {
            id: RequestId(id),
            arrival: 0,
            input_tokens: tokens,
            output_tokens: 16,
            slo: Slo::paper_default(),
            tenant: 0,
        }
    }

    fn cfg() -> BatchConfig {
        BatchConfig {
            max_prefill_tokens: 4096,
            max_prefill_reqs: 4,
            max_decode_reqs: 8,
            ring_slots: 32,
        }
    }

    #[test]
    fn prefill_batch_respects_token_budget() {
        let mut q: VecDeque<Request> =
            vec![req(0, 2000), req(1, 1500), req(2, 1500)].into();
        let b = form_prefill_batch(&mut q, &cfg());
        assert_eq!(b.requests.len(), 2);
        assert_eq!(b.total_tokens, 3500);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn into_variant_matches_and_reuses_buffer() {
        let mut q1: VecDeque<Request> = (0..10).map(|i| req(i, 700)).collect();
        let mut q2 = q1.clone();
        let mut scratch = vec![req(99, 1)]; // stale contents must be cleared
        let total = form_prefill_batch_into(&mut q1, &cfg(), &mut scratch);
        let b = form_prefill_batch(&mut q2, &cfg());
        assert_eq!(total, b.total_tokens);
        assert_eq!(
            scratch.iter().map(|r| r.id.0).collect::<Vec<_>>(),
            b.requests.iter().map(|r| r.id.0).collect::<Vec<_>>()
        );
        assert_eq!(q1.len(), q2.len());
    }

    #[test]
    fn prefill_batch_respects_request_cap() {
        let mut q: VecDeque<Request> = (0..10).map(|i| req(i, 10)).collect();
        let b = form_prefill_batch(&mut q, &cfg());
        assert_eq!(b.requests.len(), 4);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn oversized_prompt_still_admitted_alone() {
        let mut q: VecDeque<Request> = vec![req(0, 9999), req(1, 100)].into();
        let b = form_prefill_batch(&mut q, &cfg());
        assert_eq!(b.requests.len(), 1);
        assert_eq!(b.total_tokens, 9999);
    }

    #[test]
    fn fifo_order_never_reordered() {
        let mut q: VecDeque<Request> = vec![req(5, 100), req(3, 100), req(9, 100)].into();
        let b = form_prefill_batch(&mut q, &cfg());
        let ids: Vec<u64> = b.requests.iter().map(|r| r.id.0).collect();
        assert_eq!(ids, vec![5, 3, 9]);
    }

    #[test]
    fn empty_queue_empty_batch() {
        let mut q = VecDeque::new();
        let b = form_prefill_batch(&mut q, &cfg());
        assert!(b.requests.is_empty());
        assert_eq!(b.total_tokens, 0);
    }

    #[test]
    fn decode_admissions_respect_capacity() {
        let c = cfg();
        assert_eq!(decode_admissions(0, 100, &c), 8);
        assert_eq!(decode_admissions(6, 100, &c), 2);
        assert_eq!(decode_admissions(8, 100, &c), 0);
        assert_eq!(decode_admissions(2, 1, &c), 1);
    }

    #[test]
    fn ids_variant_matches_request_variant() {
        // Build the same workload twice: once as owned requests, once as
        // slab slots; both formers must admit identical batches.
        let tokens: Vec<u32> = vec![2000, 1500, 1500, 700, 700, 9999];
        let mut q_req: VecDeque<Request> =
            tokens.iter().enumerate().map(|(i, &t)| req(i as u64, t)).collect();
        let mut store: crate::util::slab::Slab<u32> = crate::util::slab::Slab::new();
        let mut q_ids: VecDeque<SlotId> = tokens.iter().map(|&t| store.insert(t)).collect();
        let c = cfg();
        loop {
            let b = form_prefill_batch(&mut q_req, &c);
            let mut ids = Vec::new();
            let total = form_prefill_batch_ids(&mut q_ids, &c, |s| *store.get(s), &mut ids);
            assert_eq!(total, b.total_tokens);
            assert_eq!(
                ids.iter().map(|&s| *store.get(s)).collect::<Vec<_>>(),
                b.requests.iter().map(|r| r.input_tokens).collect::<Vec<_>>()
            );
            if b.requests.is_empty() {
                break;
            }
        }
    }

    #[test]
    fn chunk_progress_advances_and_completes() {
        let mut p = ChunkProgress::new(req(0, 5000));
        assert_eq!(p.advance(2048), 2048);
        assert_eq!(p.advance(2048), 2048);
        assert!(!p.complete());
        assert_eq!(p.advance(2048), 904);
        assert!(p.complete());
    }

}
