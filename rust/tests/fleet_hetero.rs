//! Heterogeneous-fleet integration tests (ISSUE-4 acceptance criteria).
//!
//! * **Golden single-SKU identity**: threading per-SKU models through
//!   the cluster, router and power manager must leave every single-SKU
//!   config bit-identical — an explicit `mi300x:8` fleet (the paper's
//!   part) and the implicit no-fleet path produce the same RunResult
//!   for the shipped `configs/rapid-600.toml` and
//!   `configs/two-node-4p4d.toml`.
//! * **Mixed fleets run end-to-end** under per-SKU cap envelopes with
//!   both budget levels holding.
//! * **`scenarios/hetero-mix.toml`** loads, runs, and its study-level
//!   ShapeCheck holds: a mixed fleet under the same cluster cap
//!   achieves at least the goodput of the worst homogeneous fleet of
//!   equal GPU count.

use rapid::fleet::FleetConfig;
use rapid::scenario::{Scenario, Study};
use rapid::sim::{self, SimOptions};
use rapid::types::Slo;
use rapid::util::rng::Rng;
use rapid::workload::{build_trace, sonnet::Sonnet, ArrivalProcess};

#[path = "support/mod.rs"]
mod support;
use support::{assert_bit_identical, shipped_config};

fn trace(n: usize, qps: f64, input: u32, output: u32) -> rapid::workload::Trace {
    let mut ap = ArrivalProcess::poisson(Rng::new(71), qps);
    let mut sizes = Sonnet::new(Rng::new(72), input, output);
    build_trace(n, &mut ap, &mut sizes, Slo::paper_default())
}

/// The golden acceptance test: an explicit single-SKU `mi300x` fleet is
/// the paper's part with the controller's MIN_P/MAX_P envelope, so it
/// must reproduce the implicit (no-fleet) path bit-for-bit.
#[test]
fn single_sku_fleet_bit_identical_on_shipped_configs() {
    for (file, n, qps, input, output) in [
        ("rapid-600.toml", 250, 18.0, 4000, 32),
        ("two-node-4p4d.toml", 250, 24.0, 2048, 64),
    ] {
        let implicit = shipped_config(file);
        assert!(implicit.fleet.is_none(), "{file} must not declare a fleet");
        let mut explicit = implicit.clone();
        explicit.fleet = Some(FleetConfig::parse_mix("mi300x:8", &[]).unwrap());
        explicit.validate().unwrap();
        let t = trace(n, qps, input, output);
        let a = sim::run(&implicit, &t, &SimOptions::default());
        let b = sim::run(&explicit, &t, &SimOptions::default());
        assert_bit_identical(&a, &b);
    }
}

#[test]
fn hetero_config_runs_with_per_sku_envelopes() {
    let cfg = shipped_config("hetero-4p4d.toml");
    let fc = cfg.fleet.as_ref().expect("hetero config declares a fleet");
    assert!(fc.heterogeneous());
    // Overload enough that the RAPID controller acts.
    let t = trace(300, 20.0, 5000, 24);
    let r = sim::run(&cfg, &t, &SimOptions::default());
    assert_eq!(r.records.len(), 300, "every request gets a record");
    // Per-SKU ceilings hold at every cap-trace point: slots 2,3,6,7 are
    // a100s (max 400 W), slots 4,5 the derated part (max 650 W).
    for (at, caps) in &r.cap_trace {
        for (i, &cap) in caps.iter().enumerate() {
            let max = match i {
                2 | 3 | 6 | 7 => 400.0,
                4 | 5 => 650.0,
                _ => 750.0,
            };
            let min = match i {
                2 | 3 | 6 | 7 => 250.0,
                _ => 400.0,
            };
            assert!(
                cap <= max + 1e-6 && cap >= min - 1e-6,
                "t={at} gpu{i}: cap {cap} outside [{min}, {max}]"
            );
        }
    }
    // The node budget holds on the measured draw.
    assert!(
        r.node_power.max() <= cfg.node_budget_w + 10.0,
        "peak draw {} > budget",
        r.node_power.max()
    );
    // Deterministic under the per-SKU path too.
    let r2 = sim::run(&cfg, &t, &SimOptions::default());
    assert_bit_identical(&r, &r2);
}

#[test]
fn hetero_mix_scenario_passes_study_checks() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/hetero-mix.toml");
    let mut scenario = Scenario::from_toml_file(path).expect("shipped scenario loads");
    scenario.requests = 150; // keep the test quick; CI smoke runs it too
    let study = Study::new(scenario).run(Some(2)).expect("study runs");
    assert_eq!(study.cells.len(), 10, "5 mixes x 2 rates");
    let (passed, total) = study.checks_passed();
    assert_eq!(passed, total, "per-cell invariants hold");
    let checks = study.study_checks();
    assert_eq!(
        checks.len(),
        4,
        "2 mixed fleets x 2 rates get a worst-homogeneous comparison"
    );
    for c in &checks {
        assert!(c.pass, "{}: {}", c.what, c.detail);
    }
}

#[test]
fn mixed_fleet_beats_all_worst_fleet_under_same_cap() {
    // Direct (non-scenario) form of the acceptance ShapeCheck at a
    // saturating rate: mixed mi300x+a100 vs all-a100, equal GPU count,
    // same 4800 W node budget.
    let base = shipped_config("rapid-600.toml");
    let mut mixed = base.clone();
    mixed.fleet = Some(FleetConfig::parse_mix("mi300x:2+a100:2+mi300x:2+a100:2", &[]).unwrap());
    let mut worst = base.clone();
    worst.fleet = Some(FleetConfig::parse_mix("a100:8", &[]).unwrap());
    let t = trace(300, 14.0, 3000, 48);
    let rm = sim::run(&mixed, &t, &SimOptions::default());
    let rw = sim::run(&worst, &t, &SimOptions::default());
    assert!(
        rm.goodput_qps() + 1e-9 >= rw.goodput_qps(),
        "mixed {} qps must be >= all-worst {} qps",
        rm.goodput_qps(),
        rw.goodput_qps()
    );
}
