//! `rapid` — launcher CLI for the RAPID reproduction.
//!
//! Subcommands:
//!   fig1 | fig3 | fig4 | fig5 | fig6 | fig7 | fig8 | fig9
//!       regenerate a paper figure (table + shape checks)
//!   study    run a declarative scenario file (scenarios/*.toml)
//!   trace    traced run of one scenario cell → Chrome/Perfetto JSON
//!   explain  text timeline of one request from a traced run
//!   validate parse config/scenario TOML files, listing every error
//!   sim      run one configuration over a workload, print metrics
//!   sweep    static design-space search (the paper's §5.1 exploration)
//!   bench    hot-path perf suite + JSON report + CI regression gate
//!   serve    real PJRT serving demo (requires `make artifacts`)
//!   presets  list configuration presets

use rapid::bench::hotpath::SuiteConfig;
use rapid::bench::BenchReport;
use rapid::cli::Command;
use rapid::config::{presets, ClusterConfig};
use rapid::experiments::{self as exp, render_checks};
use rapid::scenario::{emit, Scenario, Study};
use rapid::sim::{self, SimOptions};
use rapid::types::{Slo, MILLIS, SECOND};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn common(cmd: Command) -> Command {
    cmd.opt("seed", "42", "workload RNG seed")
        .opt("requests", "1200", "requests per simulated run")
}

fn run(argv: &[String]) -> Result<(), Box<dyn std::error::Error>> {
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[] } else { &argv[1..] };
    match sub {
        "fig1" => {
            let cmd = common(Command::new("fig1", "goodput vs QPS/GPU under 4800 W"));
            let a = parse_or_help(&cmd, rest)?;
            let f = exp::fig1::run(a.u64_or("seed", 42)?, a.usize_or("requests", 1200)?);
            println!("{}", f.render());
            println!("{}", render_checks(&f.checks()));
        }
        "fig3" => {
            let cmd = common(Command::new("fig3", "uncapped node power time-series"));
            let a = parse_or_help(&cmd, rest)?;
            let f = exp::fig3::run(a.u64_or("seed", 42)?, a.usize_or("requests", 1200)?);
            println!("{}", f.render());
            println!("{}", render_checks(&f.checks()));
        }
        "fig4" => {
            let cmd = Command::new("fig4", "power/latency curves + cap step response");
            let _ = parse_or_help(&cmd, rest)?;
            let f = exp::fig4::run();
            println!("{}", f.render());
            println!("{}", render_checks(&f.checks()));
        }
        "fig5" => {
            let cmd = common(Command::new("fig5", "SLO attainment vs rate (static configs)"))
                .flag("part-b", "use the stricter TPOT = 25 ms SLO (Fig 5b)");
            let a = parse_or_help(&cmd, rest)?;
            let f = exp::fig5::run(
                a.flag("part-b"),
                a.u64_or("seed", 42)?,
                a.usize_or("requests", 1200)?,
            );
            println!("{}", f.render());
            println!("{}", render_checks(&f.checks()));
        }
        "fig6" => {
            let cmd = common(Command::new("fig6", "queueing vs execution breakdown"));
            let a = parse_or_help(&cmd, rest)?;
            let f = exp::fig6::run(a.u64_or("seed", 42)?, a.usize_or("requests", 1200)?);
            println!("{}", f.render());
            println!("{}", render_checks(&f.checks()));
        }
        "fig7" => {
            let cmd = common(Command::new("fig7", "SLO scaling sweep"));
            let a = parse_or_help(&cmd, rest)?;
            let f = exp::fig7::run(a.u64_or("seed", 42)?, a.usize_or("requests", 800)?);
            println!("{}", f.render());
            println!("{}", render_checks(&f.checks()));
        }
        "fig8" => {
            let cmd = common(Command::new("fig8", "static vs dynamic RAPID (mixed Sonnet)"))
                .opt("qps", "1.05", "per-GPU request rate (peak-load point on this substrate)");
            let a = parse_or_help(&cmd, rest)?;
            let f = exp::fig8::run(
                a.u64_or("seed", 42)?,
                a.f64_or("qps", 2.0)?,
                a.usize_or("requests", 1000)?,
            );
            println!("{}", f.render());
            println!("{}", render_checks(&f.checks()));
        }
        "fig9" => {
            let cmd = common(Command::new("fig9", "dynamic management timelines"));
            let a = parse_or_help(&cmd, rest)?;
            let f = exp::fig9::run(a.u64_or("seed", 42)?, a.usize_or("requests", 1000)?);
            println!("{}", f.render());
            println!("{}", render_checks(&f.checks()));
        }
        "sim" => {
            let cmd = common(Command::new("sim", "run one config over a workload"))
                .opt("preset", "4p4d-600", "config preset (see `rapid presets`)")
                .opt("config", "", "TOML config file (overrides preset)")
                .opt("qps", "1.5", "per-GPU request rate")
                .opt("workload", "longbench", "longbench | mixed")
                .opt("ttft-slo-ms", "1000", "TTFT SLO (ms)")
                .opt("tpot-slo-ms", "40", "TPOT SLO (ms)");
            let a = parse_or_help(&cmd, rest)?;
            let cfg = load_config(a.get("config").unwrap_or(""), a.get("preset").unwrap())?;
            let slo = Slo::new(
                a.u64_or("ttft-slo-ms", 1000)? * MILLIS,
                a.u64_or("tpot-slo-ms", 40)? * MILLIS,
            );
            let n = a.usize_or("requests", 1200)?;
            let seed = a.u64_or("seed", 42)?;
            let trace = match a.get("workload").unwrap() {
                "mixed" => rapid::workload::sonnet::mixed_phases(
                    seed,
                    rapid::workload::sonnet::MixedPhasesSpec {
                        prefill_heavy_count: n / 2,
                        decode_heavy_count: n / 2,
                        rate_qps: a.f64_or("qps", 1.5)? * cfg.total_gpus() as f64,
                        ..Default::default()
                    },
                ),
                _ => exp::longbench_trace(
                    seed,
                    a.f64_or("qps", 1.5)? * cfg.total_gpus() as f64,
                    n,
                    slo,
                ),
            };
            let res = sim::run(&cfg, &trace, &SimOptions::default());
            print_result(&cfg, &res);
        }
        "study" => {
            let cmd = Command::new(
                "study",
                "run a declarative scenario file (see scenarios/*.toml)",
            )
            .opt("format", "text", "output format: text | json | csv")
            .opt("threads", "0", "worker threads (0 = default; wins over RAPID_SWEEP_THREADS)")
            .opt("requests", "0", "override the scenario's requests/cell (0 = keep)")
            .opt("out", "", "write the emitted output to this file instead of stdout")
            .flag("progress", "live progress line on stderr (cells done, rate, ETA)");
            let a = parse_or_help(&cmd, rest)?;
            let Some(path) = a.positional.first() else {
                return Err("usage: rapid study <scenario.toml> [--format f] [--threads t]".into());
            };
            let format = a.get("format").unwrap().parse::<emit::Format>()?;
            let mut scenario = Scenario::from_toml_file(path)?;
            let requests = a.usize_or("requests", 0)?;
            if requests > 0 {
                scenario.requests = requests;
            }
            let threads = Some(a.usize_or("threads", 0)?).filter(|&t| t >= 1);
            let study = Study::new(scenario);
            let result = if a.flag("progress") {
                let t0 = std::time::Instant::now();
                let r = study.run_with_progress(threads, |done, total| {
                    let dt = t0.elapsed().as_secs_f64().max(1e-9);
                    let rate = done as f64 / dt;
                    let eta = (total - done) as f64 / rate.max(1e-9);
                    eprint!("\rstudy: {done}/{total} cells  {rate:.2} cells/s  ETA {eta:.0}s  ");
                })?;
                eprintln!();
                r
            } else {
                study.run(threads)?
            };
            let text = emit::emit(&result, format);
            match a.get("out").filter(|p| !p.is_empty()) {
                Some(out) => {
                    std::fs::write(out, &text)?;
                    println!("wrote {out}");
                }
                None => print!("{text}"),
            }
        }
        "trace" => {
            let cmd = Command::new(
                "trace",
                "run one scenario cell with the observability sink on and export a \
                 Chrome-trace-event JSON (load it at https://ui.perfetto.dev)",
            )
            .opt("cell", "", "cell selector axis=value[,axis=value...] (default: first grid cell)")
            .opt("out", "trace.json", "output path for the Chrome trace JSON")
            .opt("requests", "0", "override the scenario's requests/cell (0 = keep)");
            let a = parse_or_help(&cmd, rest)?;
            let Some(source) = a.positional.first() else {
                return Err(
                    "usage: rapid trace <scenario.toml | config.toml | preset> \
                     [--cell axis=value,...] [--out trace.json]"
                        .into(),
                );
            };
            let mut scenario = load_scenario(source)?;
            let requests = a.usize_or("requests", 0)?;
            if requests > 0 {
                scenario.requests = requests;
            }
            let selector = parse_selector(a.get("cell").unwrap_or(""))?;
            let (spec, res) = Study::new(scenario).run_traced(&selector)?;
            let obs = res.obs.as_deref().expect("traced run carries an obs report");
            let json = rapid::obs::chrome::chrome_trace(&res);
            let out = a.get("out").unwrap();
            std::fs::write(out, &json)?;
            let cell_desc = if spec.coords.is_empty() {
                "base cell".to_string()
            } else {
                spec.coords
                    .iter()
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect::<Vec<_>>()
                    .join(",")
            };
            println!(
                "traced {} ({cell_desc}): {} events ({} dropped), {} gpu steps, \
                 {} power moves, {} role flips",
                spec.config.name,
                obs.events.len() as u64 + obs.dropped,
                obs.dropped,
                obs.counters.gpu_steps,
                obs.counters.power_moves,
                obs.counters.role_flips
            );
            println!("wrote {out} — open in Perfetto (ui.perfetto.dev) or chrome://tracing");
        }
        "explain" => {
            let cmd = Command::new(
                "explain",
                "run one scenario cell traced and print a request's timeline with \
                 per-stage latency attribution",
            )
            .opt("cell", "", "cell selector axis=value[,axis=value...] (default: first grid cell)")
            .opt("requests", "0", "override the scenario's requests/cell (0 = keep)");
            let a = parse_or_help(&cmd, rest)?;
            let (Some(source), Some(rid)) = (a.positional.first(), a.positional.get(1)) else {
                return Err(
                    "usage: rapid explain <scenario.toml | config.toml | preset> <request-id> \
                     [--cell axis=value,...]"
                        .into(),
                );
            };
            let rid: u64 = rid
                .parse()
                .map_err(|_| format!("request id '{rid}' is not an integer"))?;
            let mut scenario = load_scenario(source)?;
            let requests = a.usize_or("requests", 0)?;
            if requests > 0 {
                scenario.requests = requests;
            }
            let selector = parse_selector(a.get("cell").unwrap_or(""))?;
            let (_, res) = Study::new(scenario).run_traced(&selector)?;
            print!("{}", rapid::obs::explain::explain(&res, rid)?);
        }
        "validate" => {
            let cmd = Command::new(
                "validate",
                "parse config/scenario TOML files; exit non-zero listing every error",
            );
            let a = parse_or_help(&cmd, rest)?;
            if a.positional.is_empty() {
                return Err("usage: rapid validate <file.toml>...".into());
            }
            let mut failures = 0usize;
            for path in &a.positional {
                match rapid::scenario::file::validate_path(path) {
                    Ok(kind) => println!("{path}: OK ({kind})"),
                    Err(e) => {
                        failures += 1;
                        eprintln!("{path}: {e}");
                    }
                }
            }
            if failures > 0 {
                return Err(format!("{failures} file(s) failed validation").into());
            }
        }
        "sweep" => {
            let cmd = common(Command::new(
                "sweep",
                "static design-space search: GPUs x power splits (paper §5.1), fanned across cores",
            ))
            .opt("qps", "1.5", "per-GPU request rate")
            .opt("nodes", "0", "number of identical nodes (0 = take from --config, else 1)")
            .opt("config", "", "TOML config file to use as the sweep base")
            .opt("threads", "0", "worker threads (0 = all cores; wins over RAPID_SWEEP_THREADS)");
            let a = parse_or_help(&cmd, rest)?;
            let threads = Some(a.usize_or("threads", 0)?).filter(|&t| t >= 1);
            let base = match a.get("config").unwrap_or("") {
                "" => None,
                path => Some(ClusterConfig::from_toml(&std::fs::read_to_string(path)?)?),
            };
            run_sweep(
                a.u64_or("seed", 42)?,
                a.f64_or("qps", 1.5)?,
                a.usize_or("requests", 1200)?,
                a.usize_or("nodes", 0)?,
                threads,
                base,
            );
        }
        "bench" => {
            let cmd = Command::new(
                "bench",
                "run the hot-path perf suite in-process; optionally gate on a baseline",
            )
            .opt("filter", "", "only run cases whose name contains this substring")
            .opt("json", "", "write the BenchReport JSON here (BENCH_hotpath.json schema)")
            .opt("compare", "", "baseline BenchReport JSON to gate against")
            .opt("max-regress", "25", "max tolerated per-item median-time regression (percent)")
            .opt("target-ms", "300", "per-case timing budget in ms (whole-sim case gets 5x)")
            .opt("sim-requests", "400", "requests in the whole-sim case's trace");
            let a = parse_or_help(&cmd, rest)?;
            let suite = SuiteConfig {
                filter: a.get("filter").filter(|f| !f.is_empty()).map(str::to_string),
                target_ms: a.u64_or("target-ms", 300)?,
                sim_requests: a.usize_or("sim-requests", 400)?,
                ..SuiteConfig::default()
            };
            run_bench(
                &suite,
                a.get("json").unwrap_or(""),
                a.get("compare").unwrap_or(""),
                a.f64_or("max-regress", 25.0)?,
            )?;
        }
        "presets" => {
            println!("available presets:");
            for name in presets::NAMES {
                let c = presets::by_name(name).unwrap();
                println!(
                    "  {:<16} {:<18} budget={:>5.0}W prefill={:>3.0}W decode={:>3.0}W policy={:?}",
                    name, c.name, c.node_budget_w, c.prefill_cap_w, c.decode_cap_w, c.control
                );
            }
        }
        #[cfg(feature = "pjrt")]
        "serve" => {
            let cmd = Command::new("serve", "real PJRT serving demo")
                .opt("artifacts", "artifacts", "artifact directory")
                .opt("requests", "16", "number of requests")
                .opt("qps", "4.0", "arrival rate")
                .opt("prefill-gpus", "2", "prefill workers")
                .opt("decode-gpus", "2", "decode workers");
            let a = parse_or_help(&cmd, rest)?;
            rapid::server::serve_demo(
                a.get("artifacts").unwrap(),
                a.usize_or("requests", 16)?,
                a.f64_or("qps", 4.0)?,
                a.usize_or("prefill-gpus", 2)?,
                a.usize_or("decode-gpus", 2)?,
            )?;
        }
        #[cfg(not(feature = "pjrt"))]
        "serve" => {
            return Err(
                "the real-model serving path is gated behind the `pjrt` feature, \
                 which needs the `xla` and `anyhow` crates added to Cargo.toml \
                 first (they are not vendored); see DESIGN.md §7"
                    .into(),
            );
        }
        "help" | "--help" | "-h" => {
            println!("rapid — power-aware disaggregated inference (paper reproduction)");
            println!(
                "subcommands: fig1 fig3 fig4 fig5 fig6 fig7 fig8 fig9 study trace explain \
                 validate sim sweep bench serve presets"
            );
            println!("run `rapid <subcommand> --help` for flags");
        }
        other => {
            return Err(format!("unknown subcommand '{other}' (try `rapid help`)").into());
        }
    }
    Ok(())
}

fn parse_or_help(
    cmd: &Command,
    argv: &[String],
) -> Result<rapid::cli::Args, Box<dyn std::error::Error>> {
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        println!("{}", cmd.help_text());
        std::process::exit(0);
    }
    Ok(cmd.parse(argv)?)
}

fn load_config(path: &str, preset: &str) -> Result<ClusterConfig, Box<dyn std::error::Error>> {
    if !path.is_empty() {
        let text = std::fs::read_to_string(path)?;
        return Ok(ClusterConfig::from_toml(&text)?);
    }
    Ok(presets::by_name(preset)?)
}

/// `rapid trace`/`rapid explain` input: a scenario TOML, a cluster
/// config TOML (wrapped into a one-cell scenario), or a preset name.
fn load_scenario(source: &str) -> Result<Scenario, Box<dyn std::error::Error>> {
    if std::path::Path::new(source).exists() {
        let text = std::fs::read_to_string(source)?;
        return match Scenario::from_toml(&text) {
            Ok(s) => Ok(s),
            // Not a scenario: maybe a bare cluster config. If neither,
            // the scenario grammar's error is the one to surface.
            Err(scenario_err) => match ClusterConfig::from_toml(&text) {
                Ok(cfg) => Ok(Scenario::new(source, cfg)),
                Err(_) => Err(scenario_err.into()),
            },
        };
    }
    let cfg = presets::by_name(source)?;
    Ok(Scenario::new(source, cfg))
}

/// Parse a `--cell` selector: `axis=value[,axis=value...]`.
fn parse_selector(s: &str) -> Result<Vec<(String, String)>, Box<dyn std::error::Error>> {
    let mut out = Vec::new();
    for part in s.split(',').filter(|p| !p.is_empty()) {
        let Some((k, v)) = part.split_once('=') else {
            return Err(format!("bad --cell entry '{part}' (want axis=value)").into());
        };
        out.push((k.to_string(), v.to_string()));
    }
    Ok(out)
}

fn run_bench(
    suite: &SuiteConfig,
    json_path: &str,
    baseline_path: &str,
    max_regress_pct: f64,
) -> Result<(), Box<dyn std::error::Error>> {
    let report = rapid::bench::hotpath::run_suite(suite);
    if report.entries.is_empty() {
        return Err("bench: no case matches the filter".into());
    }
    for t in &report.entries {
        println!("{}", t.report());
    }
    if !json_path.is_empty() {
        report.write(json_path)?;
        println!("wrote {json_path}");
    }
    if baseline_path.is_empty() {
        return Ok(());
    }
    let baseline = BenchReport::load(baseline_path)?;
    let comparisons = report.compare(&baseline);
    let skipped = report.entries.len() - comparisons.len();
    println!(
        "\nvs baseline {baseline_path} (median per-item time, max regression {max_regress_pct}%):"
    );
    for c in &comparisons {
        println!(
            "  {:<44} {:>12.4} us -> {:>12.4} us  {:>+7.1}%{}",
            c.name,
            c.baseline_us,
            c.current_us,
            c.delta_pct,
            if c.regressed(max_regress_pct) { "  REGRESSED" } else { "" }
        );
    }
    if skipped > 0 {
        println!("  ({skipped} case(s) without a recorded baseline — skipped)");
    }
    // A recorded baseline case this run should have measured (i.e. the
    // active filter selects it) but did not must not pass silently — it
    // means the case was renamed or removed.
    let unmatched: Vec<&str> = baseline
        .entries
        .iter()
        .filter(|b| b.is_recorded())
        .filter(|b| suite.wants(&b.name) && report.entry(&b.name).is_none())
        .map(|b| b.name.as_str())
        .collect();
    if !unmatched.is_empty() {
        return Err(format!(
            "perf gate: {} recorded baseline case(s) missing from this run: {} \
             (was the case renamed or removed?)",
            unmatched.len(),
            unmatched.join(", ")
        )
        .into());
    }
    let regressed: Vec<&str> = comparisons
        .iter()
        .filter(|c| c.regressed(max_regress_pct))
        .map(|c| c.name.as_str())
        .collect();
    if !regressed.is_empty() {
        return Err(format!(
            "perf gate: {} case(s) regressed beyond {max_regress_pct}%: {}",
            regressed.len(),
            regressed.join(", ")
        )
        .into());
    }
    println!("perf gate OK ({} case(s) within {max_regress_pct}%)", comparisons.len());
    Ok(())
}

fn print_result(cfg: &ClusterConfig, res: &rapid::metrics::RunResult) {
    println!("config: {}", cfg.name);
    println!("  requests:        {}", res.records.len());
    println!("  duration:        {:.1} s", res.duration as f64 / SECOND as f64);
    println!("  attainment:      {:.1}%", res.attainment() * 100.0);
    println!("  goodput:         {:.2} qps", res.goodput_qps());
    println!("  qps/kW:          {:.3}", res.qps_per_kw());
    let s = res.summary();
    println!("  TTFT p50/p90:    {:.0} / {:.0} ms", s.ttft_p50_ms, s.ttft_p90_ms);
    println!("  TPOT p50/p90:    {:.1} / {:.1} ms", s.tpot_p50_ms, s.tpot_p90_ms);
    let (q, e) = res.ttft_breakdown();
    println!("  queue/exec:      {:.0} / {:.0} ms", q / 1000.0, e / 1000.0);
    println!("  provisioned:     {:.0} W", res.mean_provisioned_w);
    println!("  peak node draw:  {:.0} W", res.node_power.max());
    println!("  decisions:       {}", res.decisions.len());
    println!("  sim events:      {}", res.sim_events);
}

fn run_sweep(
    seed: u64,
    qps: f64,
    n: usize,
    nodes: usize,
    threads: Option<usize>,
    base: Option<ClusterConfig>,
) {
    let base = base.unwrap_or_else(|| presets::p4d4(600.0));
    // `--nodes 0` (the default) keeps the base config's node count, so a
    // multi-node TOML passed via --config is not silently flattened.
    let nodes = if nodes == 0 { base.n_nodes } else { nodes };
    let node_budget = base.node_budget_w;
    let per_node = base.n_gpus;
    println!(
        "static design-space sweep @{qps} QPS/GPU (LongBench, {nodes} node(s) x {:.0} W, {} threads)",
        node_budget,
        exp::sweep_threads_with(threads)
    );
    // Build every sweep point first, then fan them across cores: each
    // point is an independent deterministic simulation.
    let mut points: Vec<ClusterConfig> = Vec::new();
    for p in 2..=per_node.saturating_sub(2) {
        let d = per_node - p;
        // Power splits in 25 W steps that fit the node budget exactly.
        let mut pw = 400.0;
        while pw <= 750.0 {
            let dw = (node_budget - pw * p as f64) / d as f64;
            if (400.0..=750.0).contains(&dw) {
                let mut cfg = base.clone();
                cfg.name = format!("{p}P-{pw:.0}W/{d}D-{dw:.0}W");
                cfg.topology = rapid::config::Topology::Disaggregated { prefill: p, decode: d };
                cfg.prefill_cap_w = pw;
                cfg.decode_cap_w = dw;
                cfg = presets::scaled_to_nodes(cfg, nodes);
                if cfg.validate().is_ok() {
                    points.push(cfg);
                }
            }
            pw += 25.0;
        }
    }
    let t0 = std::time::Instant::now();
    let results = exp::parallel_map_threads(&points, threads, |cfg| {
        let trace = exp::longbench_trace(
            seed,
            qps * cfg.total_gpus() as f64,
            n,
            Slo::paper_default(),
        );
        sim::run(cfg, &trace, &SimOptions::default())
    });
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{:<8}{:<12}{:<12}{:>12}{:>10}{:>14}",
        "P/D", "prefill W", "decode W", "attainment", "goodput", "peak node W"
    );
    let mut best: Option<(String, f64)> = None;
    for (cfg, res) in points.iter().zip(&results) {
        let peak_node = res
            .node_power_by_node
            .iter()
            .map(|ts| ts.max())
            .fold(f64::MIN, f64::max);
        let (p, d) = match cfg.topology {
            rapid::config::Topology::Disaggregated { prefill, decode } => (prefill, decode),
            rapid::config::Topology::Coalesced => (cfg.n_gpus, 0),
        };
        println!(
            "{:<8}{:<12.0}{:<12.0}{:>11.1}%{:>10.2}{:>14.0}",
            format!("{p}P{d}D"),
            cfg.prefill_cap_w,
            cfg.decode_cap_w,
            res.attainment() * 100.0,
            res.goodput_qps(),
            peak_node
        );
        let score = res.attainment();
        if best.as_ref().map_or(true, |(_, s)| score > *s) {
            best = Some((cfg.name.clone(), score));
        }
    }
    println!(
        "\n{} sweep points in {wall:.1}s ({:.1} points/s)",
        points.len(),
        points.len() as f64 / wall.max(1e-9)
    );
    if let Some((name, score)) = best {
        println!("best static configuration: {name} (attainment {:.1}%)", score * 100.0);
    }
}
