//! RAPID: power-aware dynamic reallocation for disaggregated LLM inference.
//!
//! Reproduction of "Power Aware Dynamic Reallocation For Inference"
//! (Jiang et al., 2026). See DESIGN.md for the architecture and the
//! paper-to-repo substitution map.

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod env;
pub mod experiments;
pub mod fleet;
pub mod kv;
pub mod mem;
pub mod metrics;
pub mod power;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scenario;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod sim;
pub mod types;
pub mod util;
pub mod workload;
