//! Fig 3: uncapped node power time-series vs the 4800 W line
//!
//! `cargo bench --bench fig3_power_trace` regenerates the figure's rows/series and
//! validates the paper-shape assertions (DESIGN.md §6). Absolute numbers
//! differ from the paper (simulated substrate); shapes must hold.

fn main() {
    let n: usize = std::env::var("RAPID_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000);
    let t0 = std::time::Instant::now();
    let f = rapid::experiments::fig3::run(42, n);
    println!("{}", f.render());
    let checks = f.checks();
    println!("{}", rapid::experiments::render_checks(&checks));
    rapid::bench::finish_figure_bench("fig3_power_trace", t0, &checks);
}
