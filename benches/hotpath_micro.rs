//! Hot-path microbenchmarks (the §Perf targets in EXPERIMENTS.md):
//!   * KV ring publish/consume round-trip,
//!   * router pick over an 8-GPU load table,
//!   * prefill batch formation,
//!   * controller decide() tick,
//!   * whole-sim throughput in simulated events/sec.
//!
//! `cargo bench --bench hotpath_micro`

use std::collections::VecDeque;

use rapid::bench::{bench, per_second};
use rapid::config::{presets, BatchConfig, ControlPolicy, ControllerConfig};
use rapid::coordinator::batcher::form_prefill_batch;
use rapid::coordinator::router::{pick_prefill, WorkerLoad};
use rapid::coordinator::{Controller, Snapshot};
use rapid::kv::KvRing;
use rapid::sim::{self, SimOptions};
use rapid::types::{GpuId, Request, RequestId, Slo, SECOND};
use rapid::util::rng::Rng;
use rapid::workload::{build_trace, sonnet::Sonnet, ArrivalProcess};

fn main() {
    // --- KV ring round trip ------------------------------------------
    let ring: KvRing<u64> = KvRing::new(32);
    let t = bench("kv_ring/publish+consume", 300, 2_000_000, || {
        ring.try_publish(1).unwrap();
        std::hint::black_box(ring.try_consume());
    });
    println!("{}   ({:.1} M ops/s)", t.report(), per_second(&t, 1) / 1e6);

    // --- router -------------------------------------------------------
    let loads: Vec<WorkerLoad> = (0..8)
        .map(|i| WorkerLoad {
            gpu: GpuId(i),
            node: 0,
            queued_tokens: (i as u64 * 37) % 5000,
            requests: i % 5,
            accepting: i != 3,
        })
        .collect();
    let t = bench("router/pick_prefill(8 gpus)", 300, 5_000_000, || {
        std::hint::black_box(pick_prefill(std::hint::black_box(&loads)));
    });
    println!("{}   ({:.1} M picks/s)", t.report(), per_second(&t, 1) / 1e6);

    // --- batch formation ----------------------------------------------
    let cfg = BatchConfig::default();
    let mk_queue = || -> VecDeque<Request> {
        (0..64)
            .map(|i| Request {
                id: RequestId(i),
                arrival: 0,
                input_tokens: 500 + (i as u32 * 131) % 3000,
                output_tokens: 64,
                slo: Slo::paper_default(),
            })
            .collect()
    };
    let mut q = mk_queue();
    let t = bench("batcher/form_prefill_batch", 300, 2_000_000, || {
        if q.len() < 8 {
            q = mk_queue();
        }
        std::hint::black_box(form_prefill_batch(&mut q, &cfg));
    });
    println!("{}", t.report());

    // --- controller tick -----------------------------------------------
    let mut ctl = Controller::new(ControllerConfig::default(), ControlPolicy::DynPowerGpu);
    for i in 0..64 {
        ctl.observe_ttft(i * 1000, 1.2);
        ctl.observe_tpot(i * 1000, 0.5);
    }
    let snap = Snapshot {
        now: 10 * SECOND,
        prefill_queue: 12,
        decode_queue: 0,
        prefill_gpus: 4,
        decode_gpus: 4,
        prefill_power_saturated: false,
        decode_power_saturated: false,
    };
    let t = bench("controller/decide", 300, 2_000_000, || {
        let mut s = snap.clone();
        s.now += 1;
        std::hint::black_box(ctl.decide(&s));
    });
    println!("{}", t.report());

    // --- end-to-end sim throughput -------------------------------------
    let cfg = presets::rapid_600();
    let mut ap = ArrivalProcess::poisson(Rng::new(1), 10.0);
    let mut sizes = Sonnet::new(Rng::new(2), 2048, 64);
    let trace = build_trace(400, &mut ap, &mut sizes, Slo::paper_default());
    // Rough event estimate: decode steps dominate; measure wall per run.
    let t = bench("sim/run(400 reqs, rapid-600)", 1500, 50, || {
        std::hint::black_box(sim::run(&cfg, &trace, &SimOptions::default()));
    });
    let res = sim::run(&cfg, &trace, &SimOptions::default());
    // Count a proxy for events: records + power samples + decisions.
    let evts = res.records.len() * 70; // ~64 decode steps + overhead per req
    println!(
        "{}   (~{:.2} M simulated events/s)",
        t.report(),
        evts as f64 / (t.mean_us / 1e6) / 1e6
    );
}
