//! Algorithm 1 — the reactive dynamic resource scheduler (paper §3.3).
//!
//! Fully observation-driven: no latency prediction, no offline profiling.
//! The controller watches recent TTFT/TPOT (normalized to each request's
//! SLO so mixed-SLO traces work), live queue pressure, and the power
//! manager's headroom, and emits one action per decision:
//!
//! ```text
//! if TTFT > SLO and |Q_P| > THRESHOLD and TPOT < SLO and cooled_down:
//!     MovePower(Decode -> Prefill)
//!     if power limits reached: MoveGpu(Decode -> Prefill); uniform caps
//! elif TPOT > SLO and TTFT < SLO and cooled_down:
//!     MovePower(Prefill -> Decode)
//!     if power limits reached: MoveGpu(Prefill -> Decode); uniform caps
//! ```
//!
//! Queue buildup is treated as an early stress indicator (pre-SLO-violation
//! trigger), and a cooldown between decisions provides hysteresis against
//! oscillation — both directly from the paper.

use crate::config::{ControlPolicy, ControllerConfig};
use crate::types::{Micros, Role};
use crate::util::stats::SlidingWindow;

/// What the controller asked for this tick (Fig 9's decision log).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Shift cap watts from the source role's pool to the other pool.
    MovePower { from: Role },
    /// Reassign one GPU from `from` to the other role, then distribute
    /// uniform power (paper line 14).
    MoveGpu { from: Role },
}

/// Live cluster signals the controller reads each tick.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub now: Micros,
    /// Total queued prefill requests (|Q_P|).
    pub prefill_queue: usize,
    /// Total queued-but-not-resident decode requests (|Q_D|).
    pub decode_queue: usize,
    pub prefill_gpus: usize,
    pub decode_gpus: usize,
    /// True if every prefill GPU cap is at max (or budget headroom is 0)
    /// so MovePower(Decode->Prefill) cannot help further.
    pub prefill_power_saturated: bool,
    /// Symmetric condition for the decode direction.
    pub decode_power_saturated: bool,
}

/// The controller: windows of SLO-normalized latency ratios + Algorithm 1.
#[derive(Debug)]
pub struct Controller {
    cfg: ControllerConfig,
    policy: ControlPolicy,
    /// TTFT samples as latency/slo ratios (>1 means violation).
    ttft: SlidingWindow,
    /// TPOT samples as latency/slo ratios.
    tpot: SlidingWindow,
    last_move: Option<Micros>,
    last_gpu_move: Option<Micros>,
}

impl Controller {
    pub fn new(cfg: ControllerConfig, policy: ControlPolicy) -> Self {
        Controller {
            ttft: SlidingWindow::new(cfg.metric_window),
            tpot: SlidingWindow::new(cfg.metric_window),
            cfg,
            policy,
            last_move: None,
            last_gpu_move: None,
        }
    }

    pub fn cfg(&self) -> &ControllerConfig {
        &self.cfg
    }

    /// Record a completed-or-projected TTFT observation (ratio to its SLO).
    pub fn observe_ttft(&mut self, now: Micros, ratio: f64) {
        self.ttft.push(now, ratio);
    }

    /// Record a decode step's per-token latency ratio to the SLO.
    pub fn observe_tpot(&mut self, now: Micros, ratio: f64) {
        self.tpot.push(now, ratio);
    }

    fn cooled_down(&self, now: Micros) -> bool {
        self.last_move
            .map_or(true, |t| now.saturating_sub(t) >= self.cfg.cooldown)
    }

    /// Role moves are costlier (drain + reload), so they get extra spacing.
    fn gpu_cooled_down(&self, now: Micros) -> bool {
        self.last_gpu_move
            .map_or(true, |t| now.saturating_sub(t) >= self.cfg.gpu_cooldown)
    }

    /// Time of the last reallocation decision (tests / traces).
    pub fn last_move(&self) -> Option<Micros> {
        self.last_move
    }

    /// Algorithm 1, one tick. Returns at most one action; the engine
    /// executes it (the controller stays side-effect free).
    pub fn decide(&mut self, snap: &Snapshot) -> Option<Action> {
        if !self.policy.is_dynamic() || !self.cooled_down(snap.now) {
            return None;
        }
        // "pXX ratio > 1.0" == "more than (100-XX)% of samples violate":
        // counted in O(n) instead of sorting the window (hot path).
        let viol_frac = (100.0 - self.cfg.trigger_percentile) / 100.0;
        let ttft_hot = self
            .ttft
            .frac_above(snap.now, 1.0)
            .map_or(false, |f| f > viol_frac);
        let tpot_hot = self
            .tpot
            .frac_above(snap.now, 1.0)
            .map_or(false, |f| f > viol_frac);

        let prefill_pressured =
            ttft_hot && snap.prefill_queue > self.cfg.queue_threshold && !tpot_hot;
        let decode_pressured = tpot_hot && !ttft_hot;

        let action = if prefill_pressured {
            self.escalate(snap.now, Role::Decode, snap.prefill_power_saturated, snap.decode_gpus)
        } else if decode_pressured {
            self.escalate(snap.now, Role::Prefill, snap.decode_power_saturated, snap.prefill_gpus)
        } else {
            None
        };
        if action.is_some() {
            self.last_move = Some(snap.now);
        }
        if let Some(Action::MoveGpu { .. }) = action {
            self.last_gpu_move = Some(snap.now);
        }
        action
    }

    /// Power first; GPU reallocation when power is exhausted (line 12/19).
    /// `from` is the donor role; `donor_gpus` its current pool size (the
    /// paper guarantees >= 1 GPU per phase).
    fn escalate(
        &self,
        now: Micros,
        from: Role,
        power_saturated: bool,
        donor_gpus: usize,
    ) -> Option<Action> {
        let can_power = self.policy.moves_power() && !power_saturated;
        if can_power {
            return Some(Action::MovePower { from });
        }
        if self.policy.moves_gpus() && donor_gpus > 1 && self.gpu_cooled_down(now) {
            return Some(Action::MoveGpu { from });
        }
        // DynPower-only with saturated power: nothing to do.
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SECOND;

    fn snap(now: Micros) -> Snapshot {
        Snapshot {
            now,
            prefill_queue: 0,
            decode_queue: 0,
            prefill_gpus: 4,
            decode_gpus: 4,
            prefill_power_saturated: false,
            decode_power_saturated: false,
        }
    }

    fn controller(policy: ControlPolicy) -> Controller {
        Controller::new(ControllerConfig::default(), policy)
    }

    fn pressure_prefill(c: &mut Controller, now: Micros) {
        for i in 0..10 {
            c.observe_ttft(now - i, 1.6); // violating
            c.observe_tpot(now - i, 0.4); // healthy
        }
    }

    fn pressure_decode(c: &mut Controller, now: Micros) {
        for i in 0..10 {
            c.observe_ttft(now - i, 0.3);
            c.observe_tpot(now - i, 1.5);
        }
    }

    #[test]
    fn prefill_pressure_moves_power_from_decode() {
        let mut c = controller(ControlPolicy::DynPowerGpu);
        let now = 10 * SECOND;
        pressure_prefill(&mut c, now);
        let mut s = snap(now);
        s.prefill_queue = 20;
        assert_eq!(c.decide(&s), Some(Action::MovePower { from: Role::Decode }));
    }

    #[test]
    fn queue_threshold_gates_prefill_trigger() {
        // Paper line 8: TTFT violation alone is not enough — the queue
        // must show structural backlog.
        let mut c = controller(ControlPolicy::DynPowerGpu);
        let now = 10 * SECOND;
        pressure_prefill(&mut c, now);
        let mut s = snap(now);
        s.prefill_queue = 2; // below THRESHOLD
        assert_eq!(c.decide(&s), None);
    }

    #[test]
    fn decode_pressure_moves_power_from_prefill() {
        let mut c = controller(ControlPolicy::DynPowerGpu);
        let now = 10 * SECOND;
        pressure_decode(&mut c, now);
        assert_eq!(
            c.decide(&snap(now)),
            Some(Action::MovePower { from: Role::Prefill })
        );
    }

    #[test]
    fn both_violated_no_action() {
        // TTFT high AND TPOT high: neither branch fires (no donor).
        let mut c = controller(ControlPolicy::DynPowerGpu);
        let now = 10 * SECOND;
        for i in 0..10 {
            c.observe_ttft(now - i, 1.5);
            c.observe_tpot(now - i, 1.5);
        }
        let mut s = snap(now);
        s.prefill_queue = 50;
        assert_eq!(c.decide(&s), None);
    }

    #[test]
    fn escalates_to_gpu_move_when_power_saturated() {
        let mut c = controller(ControlPolicy::DynPowerGpu);
        let now = 10 * SECOND;
        pressure_prefill(&mut c, now);
        let mut s = snap(now);
        s.prefill_queue = 20;
        s.prefill_power_saturated = true;
        assert_eq!(c.decide(&s), Some(Action::MoveGpu { from: Role::Decode }));
    }

    #[test]
    fn gpu_move_respects_min_one_per_phase() {
        let mut c = controller(ControlPolicy::DynPowerGpu);
        let now = 10 * SECOND;
        pressure_prefill(&mut c, now);
        let mut s = snap(now);
        s.prefill_queue = 20;
        s.prefill_power_saturated = true;
        s.decode_gpus = 1; // last decode GPU: must not be taken
        assert_eq!(c.decide(&s), None);
    }

    #[test]
    fn cooldown_blocks_consecutive_moves() {
        let mut c = controller(ControlPolicy::DynPowerGpu);
        let now = 10 * SECOND;
        pressure_prefill(&mut c, now);
        let mut s = snap(now);
        s.prefill_queue = 20;
        assert!(c.decide(&s).is_some());
        // Immediately after: blocked.
        pressure_prefill(&mut c, now + 1);
        s.now = now + 1;
        assert_eq!(c.decide(&s), None);
        // After cooldown: allowed again.
        let later = now + ControllerConfig::default().cooldown;
        pressure_prefill(&mut c, later);
        s.now = later;
        assert!(c.decide(&s).is_some());
    }

    #[test]
    fn static_policy_never_acts() {
        let mut c = controller(ControlPolicy::Static);
        let now = 10 * SECOND;
        pressure_prefill(&mut c, now);
        let mut s = snap(now);
        s.prefill_queue = 100;
        assert_eq!(c.decide(&s), None);
    }

    #[test]
    fn dyn_power_only_never_moves_gpus() {
        let mut c = controller(ControlPolicy::DynPower);
        let now = 10 * SECOND;
        pressure_prefill(&mut c, now);
        let mut s = snap(now);
        s.prefill_queue = 20;
        s.prefill_power_saturated = true;
        assert_eq!(c.decide(&s), None, "DynPower must not escalate to MoveGpu");
    }

    #[test]
    fn dyn_gpu_only_goes_straight_to_gpu_move() {
        let mut c = controller(ControlPolicy::DynGpu);
        let now = 10 * SECOND;
        pressure_prefill(&mut c, now);
        let mut s = snap(now);
        s.prefill_queue = 20;
        // power not saturated, but DynGpu cannot move power
        assert_eq!(c.decide(&s), Some(Action::MoveGpu { from: Role::Decode }));
    }

    #[test]
    fn healthy_metrics_no_action() {
        let mut c = controller(ControlPolicy::DynPowerGpu);
        let now = 10 * SECOND;
        for i in 0..10 {
            c.observe_ttft(now - i, 0.5);
            c.observe_tpot(now - i, 0.5);
        }
        let mut s = snap(now);
        s.prefill_queue = 100; // queue alone is not a trigger
        assert_eq!(c.decide(&s), None);
    }

    #[test]
    fn stale_window_means_no_signal() {
        let mut c = controller(ControlPolicy::DynPowerGpu);
        pressure_prefill(&mut c, SECOND);
        // 20 s later the samples have aged out; no action.
        let mut s = snap(21 * SECOND);
        s.prefill_queue = 50;
        assert_eq!(c.decide(&s), None);
    }
}
