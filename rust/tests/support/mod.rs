//! Shared helpers for the golden integration tests. Pulled in per test
//! target via `#[path = "support/mod.rs"] mod support;` — files under
//! `rust/tests/` are not auto-discovered with this non-standard layout,
//! so this module is never compiled as its own test target.

use rapid::config::ClusterConfig;
use rapid::metrics::RunResult;

/// Load one of the shipped `configs/*.toml` files.
pub fn shipped_config(name: &str) -> ClusterConfig {
    let path = format!("{}/configs/{name}", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path).expect("shipped config");
    ClusterConfig::from_toml(&text).expect("config parses")
}

/// The golden identity comparator: every record, decision, cap-trace
/// point and power sample must match to the bit. Extend HERE when
/// `RunResult` grows a series that golden tests must cover.
pub fn assert_bit_identical(a: &RunResult, b: &RunResult) {
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.prefill_start, y.prefill_start);
        assert_eq!(x.first_token, y.first_token);
        assert_eq!(x.finish, y.finish);
    }
    assert_eq!(a.decisions, b.decisions, "controller decisions must match");
    assert_eq!(a.sim_events, b.sim_events);
    assert_eq!(a.cap_trace.len(), b.cap_trace.len());
    for ((ta, capsa), (tb, capsb)) in a.cap_trace.iter().zip(&b.cap_trace) {
        assert_eq!(ta, tb);
        for (ca, cb) in capsa.iter().zip(capsb) {
            assert_eq!(ca.to_bits(), cb.to_bits(), "cap targets must be bit-identical");
        }
    }
    assert_eq!(a.node_power.points.len(), b.node_power.points.len());
    for (pa, pb) in a.node_power.points.iter().zip(&b.node_power.points) {
        assert_eq!(pa.0, pb.0);
        assert_eq!(pa.1.to_bits(), pb.1.to_bits(), "power samples must be bit-identical");
    }
    assert_eq!(a.mean_provisioned_w.to_bits(), b.mean_provisioned_w.to_bits());
    assert_eq!(a.env_events, b.env_events, "applied disturbances must match");
    assert_eq!(a.budget_trace, b.budget_trace);
    assert_eq!(a.mem, b.mem, "memory summaries must match");
    assert_eq!(a.mem_trace.len(), b.mem_trace.len());
    for ((ta, oa), (tb, ob)) in a.mem_trace.iter().zip(&b.mem_trace) {
        assert_eq!(ta, tb);
        assert_eq!(oa.to_bits(), ob.to_bits(), "occupancy samples must be bit-identical");
    }
    assert_eq!(a.obs, b.obs, "obs reports must match (None for untraced runs)");
}
