//! Central request router (paper §3.2).
//!
//! "A central scheduler process receives incoming requests, routes them
//! to a specific worker, and coordinates inter-stage communication."
//! Routing is least-loaded: prefill by queued prompt tokens (prompt cost
//! is token-proportional), decode by active+pending request count
//! (decode cost is batch-slot-proportional).

use crate::types::GpuId;

/// Load summary of one candidate worker, as the router sees it.
#[derive(Debug, Clone, Copy)]
pub struct WorkerLoad {
    pub gpu: GpuId,
    /// Queued prompt tokens (prefill) — the unit of prefill backlog.
    pub queued_tokens: u64,
    /// Queued + active requests — the unit of decode occupancy.
    pub requests: usize,
    /// Workers mid-drain are not eligible.
    pub accepting: bool,
}

/// Pick the prefill worker with the least queued prompt tokens.
pub fn pick_prefill(loads: &[WorkerLoad]) -> Option<GpuId> {
    loads
        .iter()
        .filter(|l| l.accepting)
        .min_by_key(|l| (l.queued_tokens, l.requests, l.gpu.0))
        .map(|l| l.gpu)
}

/// Pick the decode worker with the fewest resident requests.
pub fn pick_decode(loads: &[WorkerLoad]) -> Option<GpuId> {
    loads
        .iter()
        .filter(|l| l.accepting)
        .min_by_key(|l| (l.requests, l.queued_tokens, l.gpu.0))
        .map(|l| l.gpu)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(gpu: usize, tokens: u64, reqs: usize, accepting: bool) -> WorkerLoad {
        WorkerLoad {
            gpu: GpuId(gpu),
            queued_tokens: tokens,
            requests: reqs,
            accepting,
        }
    }

    #[test]
    fn prefill_prefers_fewest_tokens() {
        let loads = [load(0, 5000, 1, true), load(1, 200, 9, true), load(2, 3000, 0, true)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(1)));
    }

    #[test]
    fn decode_prefers_fewest_requests() {
        let loads = [load(0, 0, 7, true), load(1, 0, 2, true), load(2, 0, 4, true)];
        assert_eq!(pick_decode(&loads), Some(GpuId(1)));
    }

    #[test]
    fn draining_workers_skipped() {
        let loads = [load(0, 0, 0, false), load(1, 9000, 30, true)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(1)));
        assert_eq!(pick_decode(&loads), Some(GpuId(1)));
        let none = [load(0, 0, 0, false)];
        assert_eq!(pick_prefill(&none), None);
    }

    #[test]
    fn ties_break_by_gpu_id_for_determinism() {
        let loads = [load(2, 100, 1, true), load(0, 100, 1, true), load(1, 100, 1, true)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(0)));
        assert_eq!(pick_decode(&loads), Some(GpuId(0)));
    }

    #[test]
    fn empty_pool_is_none() {
        assert_eq!(pick_prefill(&[]), None);
        assert_eq!(pick_decode(&[]), None);
    }
}
