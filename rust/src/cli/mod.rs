//! Declarative CLI argument parser (offline substitute for `clap`).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean flags,
//! defaults, and generated `--help` text — the subset `rapid` needs.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    BadValue { flag: String, msg: String },
    Usage(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(n) => write!(f, "unknown flag '--{n}' (see --help)"),
            CliError::MissingValue(n) => write!(f, "flag '--{n}' needs a value"),
            CliError::BadValue { flag, msg } => write!(f, "invalid value for '--{flag}': {msg}"),
            CliError::Usage(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for CliError {}

/// One flag specification.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None = boolean flag (presence = true).
    pub default: Option<&'static str>,
    pub takes_value: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    present: Vec<String>,
    /// Positional arguments after flags.
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.values.get(name) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|e| CliError::BadValue {
                flag: name.to_string(),
                msg: format!("{e}"),
            }),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        Ok(self.get_parsed::<f64>(name)?.unwrap_or(default))
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        Ok(self.get_parsed::<u64>(name)?.unwrap_or(default))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        Ok(self.get_parsed::<usize>(name)?.unwrap_or(default))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.present.iter().any(|p| p == name)
    }
}

/// A subcommand with its flags.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            flags: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: None,
            takes_value: false,
        });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some(default),
            takes_value: true,
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut out = format!("rapid {} — {}\n\nflags:\n", self.name, self.about);
        for f in &self.flags {
            let default = f
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            out.push_str(&format!("  --{:<22} {}{}\n", f.name, f.help, default));
        }
        out
    }

    /// Parse `argv` (after the subcommand name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        // Seed defaults.
        for f in &self.flags {
            if let Some(d) = f.default {
                args.values.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let Some(spec) = self.flags.iter().find(|f| f.name == name) else {
                    return Err(CliError::UnknownFlag(name));
                };
                if spec.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    args.values.insert(name, value);
                } else {
                    args.present.push(name);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("sim", "run a simulation")
            .opt("preset", "4p4d-600", "configuration preset")
            .opt("qps", "1.5", "per-GPU request rate")
            .opt("requests", "1200", "number of requests")
            .flag("verbose", "chatty output")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&argv(&["--qps", "2.0"])).unwrap();
        assert_eq!(a.get("preset"), Some("4p4d-600"));
        assert_eq!(a.f64_or("qps", 0.0).unwrap(), 2.0);
        assert_eq!(a.usize_or("requests", 0).unwrap(), 1200);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_bool_flags() {
        let a = cmd().parse(&argv(&["--qps=0.75", "--verbose"])).unwrap();
        assert_eq!(a.f64_or("qps", 0.0).unwrap(), 0.75);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(matches!(
            cmd().parse(&argv(&["--nope"])),
            Err(CliError::UnknownFlag(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            cmd().parse(&argv(&["--qps"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_value_reports_flag() {
        let a = cmd().parse(&argv(&["--qps", "fast"])).unwrap();
        assert!(matches!(
            a.f64_or("qps", 0.0),
            Err(CliError::BadValue { .. })
        ));
    }

    #[test]
    fn positional_args_collected() {
        let a = cmd().parse(&argv(&["out.csv", "--verbose"])).unwrap();
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn help_text_lists_flags() {
        let h = cmd().help_text();
        assert!(h.contains("--preset"));
        assert!(h.contains("default: 1200"));
    }
}
