"""Layer-1 Pallas kernels: prefill flash attention, decode attention, SwiGLU.

These are the compute hot-spots of the serving engine (the analogue of the
paper's GPU attention kernels). The paper targets AMD GPUs; per the
hardware-adaptation rule we re-think the kernels for the TPU execution
model instead of porting threadblock structure:

  * prefill attention is a flash-attention-style *block-tiled* kernel:
    `BlockSpec` tiles queries along the sequence axis into VMEM-sized
    blocks and streams K/V block-by-block with an online-softmax
    accumulator — the BlockSpec/grid expression of the HBM<->VMEM schedule
    a CUDA kernel would express with threadblocks + shared memory;
  * the MXU-facing work is the two matmuls per block (`q @ k^T`, `p @ v`),
    kept in fp32 accumulation;
  * decode attention is a single-query, bandwidth-bound kernel tiled along
    the KV axis.

All kernels are compiled with `interpret=True`: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
round-trips through the rust loader (see /opt/xla-example/README.md).
Correctness is pinned to `ref.py` by python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Interpret mode is mandatory on this target (CPU PJRT); kept as a module
# switch so a real-TPU build only has to flip it.
INTERPRET = True


# ---------------------------------------------------------------------------
# Prefill: causal flash attention
# ---------------------------------------------------------------------------


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale, block_q, block_kv):
    """One (batch, head, q-block) grid step of causal flash attention.

    q_ref: (1, 1, block_q, d) VMEM tile of queries.
    k_ref/v_ref: (1, 1, seq, d) — the full K/V stream for this (b, h); the
      kernel walks it in `block_kv` chunks with an online softmax, touching
      only the blocks the causal mask allows.
    """
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (block_q, d)
    iq = pl.program_id(2)
    seq = k_ref.shape[2]
    d = q.shape[-1]

    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)  # (block_q,)

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)

    # Causal: only kv blocks with start <= last q position contribute.
    n_blocks = iq * (block_q // block_kv) + (block_q // block_kv)

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(
            k_ref[0, 0], (j * block_kv, 0), (block_kv, d)
        ).astype(jnp.float32)
        v = jax.lax.dynamic_slice(
            v_ref[0, 0], (j * block_kv, 0), (block_kv, d)
        ).astype(jnp.float32)
        s = q @ k.T  # (block_q, block_kv)
        kv_pos = j * block_kv + jax.lax.iota(jnp.int32, block_kv)
        s = jnp.where(kv_pos[None, :] <= q_pos[:, None], s, NEG_INF)

        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.named_call, name="pallas_prefill_attention")
def prefill_attention(q, k, v, *, sm_scale=None, block_q=64, block_kv=64):
    """Causal multi-head attention over a padded prompt (flash-style).

    Args:
      q, k, v: f32[batch, heads, seq, head_dim]; `seq` must be a multiple
        of `block_q`, and `block_q` of `block_kv`.

    Returns:
      f32[batch, heads, seq, head_dim]
    """
    b, h, s, d = q.shape
    if sm_scale is None:
        sm_scale = float(1.0 / (d**0.5))
    block_q = min(block_q, s)
    block_kv = min(block_kv, block_q)
    if s % block_q or block_q % block_kv:
        raise ValueError(f"seq={s} not tileable by ({block_q}, {block_kv})")

    grid = (b, h, s // block_q)
    kernel = functools.partial(
        _prefill_kernel, sm_scale=sm_scale, block_q=block_q, block_kv=block_kv
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, s, d), lambda ib, ih, iq: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda ib, ih, iq: (ib, ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=INTERPRET,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Decode: single-query attention over the KV cache
# ---------------------------------------------------------------------------


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, sm_scale, block_kv):
    """One (batch, head) grid step: q attends to cache slots `<= pos`."""
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (d,)
    pos = pos_ref[0]
    seq = k_ref.shape[2]
    d = q.shape[-1]

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), jnp.float32)

    # Only blocks that contain live slots (<= pos) are visited.
    n_blocks = (pos + 1 + block_kv - 1) // block_kv

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(
            k_ref[0, 0], (j * block_kv, 0), (block_kv, d)
        ).astype(jnp.float32)
        v = jax.lax.dynamic_slice(
            v_ref[0, 0], (j * block_kv, 0), (block_kv, d)
        ).astype(jnp.float32)
        s = k @ q  # (block_kv,)
        kv_pos = j * block_kv + jax.lax.iota(jnp.int32, block_kv)
        s = jnp.where(kv_pos <= pos, s, NEG_INF)

        m_new = jnp.maximum(m, s.max())
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum()
        acc_new = acc * alpha + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0, 0] = (acc / l).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, sm_scale=None, block_kv=64):
    """Single-step decode attention.

    Args:
      q: f32[batch, heads, head_dim] — query at slot `pos`.
      k_cache, v_cache: f32[batch, heads, max_seq, head_dim].
      pos: i32[batch] — live slots are `<= pos` per batch element.

    Returns:
      f32[batch, heads, head_dim]
    """
    b, h, s, d = k_cache.shape
    if sm_scale is None:
        sm_scale = float(1.0 / (d**0.5))
    block_kv = min(block_kv, s)
    if s % block_kv:
        raise ValueError(f"max_seq={s} not tileable by block_kv={block_kv}")

    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale, block_kv=block_kv)
    out = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih: (ib,)),
            pl.BlockSpec((1, 1, d), lambda ib, ih: (ib, ih, 0)),
            pl.BlockSpec((1, 1, s, d), lambda ib, ih: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda ib, ih: (ib, ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda ib, ih: (ib, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=INTERPRET,
    )(pos, q, k_cache, v_cache)
    return out


# ---------------------------------------------------------------------------
# SwiGLU feed-forward
# ---------------------------------------------------------------------------


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """Row-block SwiGLU: both matmuls + the gated activation fused in VMEM."""
    x = x_ref[...].astype(jnp.float32)
    g = x @ wg_ref[...].astype(jnp.float32)
    u = x @ wu_ref[...].astype(jnp.float32)
    act = g * jax.lax.logistic(g) * u
    o_ref[...] = (act @ wd_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def swiglu_ffn(x, w_gate, w_up, w_down, *, block_rows=64):
    """SwiGLU FFN with row-blocked fusion.

    Args:
      x: f32[rows, d_model]; rows must be a multiple of block_rows (or
        smaller than it).
      w_gate, w_up: f32[d_model, d_ff]; w_down: f32[d_ff, d_model].
    """
    n, dm = x.shape
    d_ff = w_gate.shape[1]
    block_rows = min(block_rows, n)
    if n % block_rows:
        raise ValueError(f"rows={n} not tileable by block_rows={block_rows}")

    return pl.pallas_call(
        _swiglu_kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, dm), lambda i: (i, 0)),
            pl.BlockSpec((dm, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((dm, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_ff, dm), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, dm), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dm), x.dtype),
        interpret=INTERPRET,
    )(x, w_gate, w_up, w_down)
