//! Arrival processes: Poisson (the paper's model) and a bursty variant.

use crate::types::{Micros, SECOND};
use crate::util::rng::Rng;

/// Optional burst structure layered on the base process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Burstiness {
    /// Plain Poisson (paper §4).
    None,
    /// Markov-modulated Poisson: alternate calm/burst regimes. `factor`
    /// multiplies the rate during bursts; `burst_frac` is the fraction of
    /// time spent bursting. Models the "bursty request rates" of §3.
    Markov { factor: f64, burst_frac: f64, mean_dwell: Micros },
}

#[derive(Debug, Clone)]
pub struct ArrivalProcess {
    rng: Rng,
    /// Base rate, requests per second.
    rate: f64,
    burst: Burstiness,
    /// Current regime: true while bursting.
    bursting: bool,
    regime_until: Micros,
}

impl ArrivalProcess {
    pub fn poisson(rng: Rng, rate_qps: f64) -> Self {
        assert!(rate_qps > 0.0);
        ArrivalProcess {
            rng,
            rate: rate_qps,
            burst: Burstiness::None,
            bursting: false,
            regime_until: 0,
        }
    }

    pub fn bursty(rng: Rng, rate_qps: f64, factor: f64, burst_frac: f64) -> Self {
        assert!(factor > 1.0 && (0.0..1.0).contains(&burst_frac));
        ArrivalProcess {
            rng,
            rate: rate_qps,
            burst: Burstiness::Markov {
                factor,
                burst_frac,
                mean_dwell: 2 * SECOND,
            },
            bursting: false,
            regime_until: 0,
        }
    }

    fn current_rate(&mut self, now: Micros) -> f64 {
        match self.burst {
            Burstiness::None => self.rate,
            Burstiness::Markov {
                factor,
                burst_frac,
                mean_dwell,
            } => {
                if now >= self.regime_until {
                    // Flip regimes; dwell times keep the long-run burst
                    // fraction at `burst_frac`.
                    self.bursting = self.rng.chance(burst_frac);
                    let dwell = self.rng.exponential(1.0 / (mean_dwell as f64 / 1e6));
                    self.regime_until = now + (dwell * 1e6) as Micros;
                }
                if self.bursting {
                    // Keep the long-run mean rate equal to `rate`:
                    // burst at rate*factor, calm below rate.
                    self.rate * factor
                } else {
                    self.rate * (1.0 - burst_frac * factor).max(0.05)
                        / (1.0 - burst_frac)
                }
            }
        }
    }

    /// Next arrival strictly after `t`.
    pub fn next_after(&mut self, t: Micros) -> Micros {
        let rate = self.current_rate(t);
        let gap = self.rng.exponential(rate);
        t + (gap * 1e6).max(1.0) as Micros
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate() {
        let mut ap = ArrivalProcess::poisson(Rng::new(5), 20.0);
        let mut t = 0;
        let n = 20_000;
        for _ in 0..n {
            t = ap.next_after(t);
        }
        let measured = n as f64 / (t as f64 / 1e6);
        assert!((measured / 20.0 - 1.0).abs() < 0.05, "rate={measured}");
    }

    #[test]
    fn poisson_cv_is_one() {
        // Coefficient of variation of exponential gaps ~ 1.
        let mut ap = ArrivalProcess::poisson(Rng::new(6), 50.0);
        let mut t = 0;
        let mut gaps = Vec::new();
        for _ in 0..20_000 {
            let nt = ap.next_after(t);
            gaps.push((nt - t) as f64);
            t = nt;
        }
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.05, "cv={cv}");
    }

    #[test]
    fn bursty_has_higher_variance_than_poisson() {
        let mut calm = ArrivalProcess::poisson(Rng::new(7), 20.0);
        let mut bursty = ArrivalProcess::bursty(Rng::new(7), 20.0, 4.0, 0.2);
        let count_in_windows = |ap: &mut ArrivalProcess| {
            let mut t = 0u64;
            let mut counts = vec![0u32; 200];
            loop {
                t = ap.next_after(t);
                let w = (t / SECOND) as usize;
                if w >= counts.len() {
                    break;
                }
                counts[w] += 1;
            }
            let mean = counts.iter().map(|&c| c as f64).sum::<f64>() / counts.len() as f64;
            let var = counts
                .iter()
                .map(|&c| (c as f64 - mean).powi(2))
                .sum::<f64>()
                / counts.len() as f64;
            var / mean // index of dispersion; 1 for Poisson
        };
        let d_calm = count_in_windows(&mut calm);
        let d_bursty = count_in_windows(&mut bursty);
        assert!(d_calm < 1.5, "poisson dispersion {d_calm}");
        assert!(d_bursty > d_calm, "bursty {d_bursty} vs calm {d_calm}");
    }

    #[test]
    fn bursty_mean_rate_matches_rate_qps_over_long_horizon() {
        // The calm-regime rate is derated so the long-run mean stays at
        // `rate_qps` despite the 4x bursts: 0.2*4r + 0.8*0.25r = r.
        let mut ap = ArrivalProcess::bursty(Rng::new(11), 25.0, 4.0, 0.2);
        let mut t = 0;
        let n = 100_000;
        for _ in 0..n {
            t = ap.next_after(t);
        }
        let measured = n as f64 / (t as f64 / 1e6);
        assert!(
            (measured / 25.0 - 1.0).abs() < 0.1,
            "long-run rate {measured} vs 25.0"
        );
    }

    #[test]
    fn bursty_regime_dwell_times_match_spec() {
        // Drive the process and reconstruct regime segments from the
        // internal state: dwell durations are exponential with mean
        // `mean_dwell` (2 s), and the burst-time fraction converges to
        // `burst_frac`.
        let burst_frac = 0.3;
        let mut ap = ArrivalProcess::bursty(Rng::new(13), 50.0, 3.0, burst_frac);
        let Burstiness::Markov { mean_dwell, .. } = ap.burst else {
            panic!("bursty process must be Markov-modulated");
        };
        let mut t = 0;
        let mut segments: Vec<(bool, f64)> = Vec::new(); // (bursting, dwell us)
        let mut seg_start = 0u64;
        let mut seg_until = 0u64;
        let mut seg_bursting = false;
        let mut first = true;
        while segments.len() < 4000 {
            t = ap.next_after(t);
            if ap.regime_until != seg_until {
                if !first {
                    segments.push((seg_bursting, (seg_until - seg_start) as f64));
                }
                first = false;
                seg_start = seg_until;
                seg_until = ap.regime_until;
                seg_bursting = ap.bursting;
            }
        }
        let mean = segments.iter().map(|&(_, d)| d).sum::<f64>() / segments.len() as f64;
        assert!(
            (mean / mean_dwell as f64 - 1.0).abs() < 0.1,
            "mean dwell {mean} vs {mean_dwell}"
        );
        // Exponential dwell: CV ~ 1.
        let var = segments
            .iter()
            .map(|&(_, d)| (d - mean).powi(2))
            .sum::<f64>()
            / segments.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.15, "dwell cv={cv}");
        // Time-weighted burst fraction ~ burst_frac (regime draws are
        // iid Bernoulli(burst_frac) with iid dwells).
        let burst_time: f64 = segments.iter().filter(|&&(b, _)| b).map(|&(_, d)| d).sum();
        let total_time: f64 = segments.iter().map(|&(_, d)| d).sum();
        let frac = burst_time / total_time;
        assert!(
            (frac - burst_frac).abs() < 0.05,
            "burst fraction {frac} vs {burst_frac}"
        );
    }

    #[test]
    fn bursty_burst_rate_exceeds_calm_rate() {
        // Within a single regime the process is Poisson at the regime
        // rate; gaps drawn while bursting must be ~factor x shorter.
        let mut ap = ArrivalProcess::bursty(Rng::new(17), 20.0, 4.0, 0.2);
        let mut t = 0;
        let (mut burst_gaps, mut calm_gaps) = (Vec::new(), Vec::new());
        for _ in 0..200_000 {
            let nt = ap.next_after(t);
            // Classify by the regime that produced the gap: next_after
            // resolves the regime at `t` before drawing.
            if ap.bursting {
                burst_gaps.push((nt - t) as f64);
            } else {
                calm_gaps.push((nt - t) as f64);
            }
            t = nt;
        }
        assert!(burst_gaps.len() > 1000 && calm_gaps.len() > 1000);
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let ratio = mean(&calm_gaps) / mean(&burst_gaps);
        // burst rate = 4r, calm rate = 0.25r -> gap ratio ~ 16 (allow
        // slack for regime-boundary gaps attributed to the wrong side).
        assert!(ratio > 8.0, "calm/burst gap ratio {ratio}");
    }

    #[test]
    fn arrivals_strictly_increase() {
        let mut ap = ArrivalProcess::poisson(Rng::new(8), 1000.0);
        let mut t = 0;
        for _ in 0..1000 {
            let nt = ap.next_after(t);
            assert!(nt > t);
            t = nt;
        }
    }
}
