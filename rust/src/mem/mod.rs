//! KV memory subsystem (DESIGN.md §14): per-GPU HBM capacity
//! accounting, tiered offload, and a conversation-keyed prefix cache.
//!
//! The paper's reallocation model pays bandwidth for KV transfers but
//! never capacity — decode can always admit, and multi-turn prompts
//! always re-prefill. This module makes KV memory a first-class
//! resource, following the MemDis-LLM tier shape (local HBM → remote
//! memory → disk, each with its own bandwidth/latency) and the
//! TensorRT-LLM KV-cache-exchange design for conversation reuse:
//!
//! * [`MemConfig`] — the `[mem]` TOML table: an optional uniform HBM
//!   capacity override (per-SKU `hbm_gb` catalog values apply when
//!   unset), tier bandwidths/latencies (validated `local ≥ remote ≥
//!   disk`), and the prefix-cache switch;
//! * [`MemState`] — per-GPU pools the cluster core drives: decode
//!   dispatch **reserves** the request's projected context bytes
//!   (prompt + cached prefix + generated tokens, the same sizing the
//!   failure re-fetch path uses) and eviction demotes least-recently
//!   finished cached blocks local → remote → disk to make headroom.
//!   Active reservations are never victims, so `resident ≤ capacity`
//!   holds at every instant by construction (the per-cell ShapeCheck);
//! * a prefix cache keyed by conversation id: a finished turn's KV
//!   parks as a cached block, and the next turn of that conversation
//!   skips re-prefilling the reused prefix, paying only the tier fetch.
//!
//! **Bit-identity contract**: without a `[mem]` table (`ClusterConfig::
//! mem == None`) the subsystem is inert — no reservations, no stalls, a
//! memory-pressure term of exactly `+0.0` in the router — and every run
//! is bit-identical to the pre-mem simulator. Coalesced topologies keep
//! the subsystem inert too (their KV never crosses the ring).
//!
//! ```
//! use rapid::mem::MemAxis;
//!
//! let axis = MemAxis::parse_compact("multiturn:4:0.6+hbm:32").unwrap();
//! assert!(axis.hbm_gb.is_some() && axis.multiturn.is_some());
//! assert!(MemAxis::parse_compact("none").unwrap().is_empty());
//! ```

use std::collections::{HashMap, VecDeque};

use crate::types::Micros;

/// The `[mem]` config table: HBM capacity plus the offload tier model.
#[derive(Debug, Clone, PartialEq)]
pub struct MemConfig {
    /// Uniform per-GPU HBM capacity override (GB). `None` falls back to
    /// each slot's SKU `hbm_gb`; a slot with neither is uncapped.
    pub hbm_gb: Option<f64>,
    /// Per-GPU remote-tier (CXL/host-memory class) capacity (GB).
    pub remote_gb: f64,
    /// Local HBM-side eviction/fetch bandwidth (GB/s), XGMI-class.
    pub local_bw_gbps: f64,
    /// Remote-tier bandwidth (GB/s).
    pub remote_bw_gbps: f64,
    /// Disk-tier bandwidth (GB/s). The disk tier is unbounded.
    pub disk_bw_gbps: f64,
    /// Added latency for any remote-tier touch (us).
    pub remote_lat_us: Micros,
    /// Added latency for any disk-tier touch (us).
    pub disk_lat_us: Micros,
    /// Keep finished conversations' KV as prefix-cache blocks.
    pub prefix_cache: bool,
}

impl Default for MemConfig {
    fn default() -> Self {
        MemConfig {
            hbm_gb: None,
            remote_gb: 512.0,
            local_bw_gbps: 64.0,
            remote_bw_gbps: 16.0,
            disk_bw_gbps: 2.0,
            remote_lat_us: 50,
            disk_lat_us: 2_000,
            prefix_cache: true,
        }
    }
}

impl MemConfig {
    /// Structural checks `rapid validate` enforces: positive
    /// capacities/bandwidths and the tier ordering local ≥ remote ≥ disk.
    pub fn validate(&self) -> Result<(), String> {
        if let Some(gb) = self.hbm_gb {
            if gb <= 0.0 {
                return Err(format!("mem.hbm_gb {gb} must be > 0"));
            }
        }
        if self.remote_gb <= 0.0 {
            return Err(format!("mem.remote_gb {} must be > 0", self.remote_gb));
        }
        for (name, bw) in [
            ("local_bw_gbps", self.local_bw_gbps),
            ("remote_bw_gbps", self.remote_bw_gbps),
            ("disk_bw_gbps", self.disk_bw_gbps),
        ] {
            if bw <= 0.0 {
                return Err(format!("mem.{name} {bw} must be > 0"));
            }
        }
        if self.local_bw_gbps < self.remote_bw_gbps || self.remote_bw_gbps < self.disk_bw_gbps {
            return Err(format!(
                "mem tier bandwidths must be ordered local >= remote >= disk \
                 (got {} >= {} >= {})",
                self.local_bw_gbps, self.remote_bw_gbps, self.disk_bw_gbps
            ));
        }
        Ok(())
    }
}

/// Where a cached (finished-context) KV block currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    Local,
    Remote,
    Disk,
}

/// One finished conversation's parked KV: the prefix-cache unit and the
/// eviction victim unit (whole conversations demote atomically).
#[derive(Debug, Clone, Copy)]
struct CachedBlock {
    conv: u64,
    bytes: u64,
    tokens: u32,
}

/// Result of a successful reservation: the eviction work it forced.
/// `time == 0` when the pool had headroom without demoting anything.
#[derive(Debug, Clone, Copy, Default)]
pub struct Eviction {
    /// Simulated time the demotions occupy the GPU's copy engines
    /// (decode on the GPU stalls until `now + time`).
    pub time: Micros,
    /// Bytes demoted out of local HBM.
    pub bytes: u64,
}

/// Per-run memory counters surfaced on `Summary`/emitters.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct MemSummary {
    /// Peak HBM occupancy fraction over finite-capacity GPUs.
    pub peak_occupancy: f64,
    /// Cached blocks demoted out of local HBM.
    pub evictions: u64,
    /// Bytes those demotions moved to remote/disk tiers.
    pub offload_bytes: u64,
    /// Prefix-cache hits / lookups and their ratio.
    pub prefix_hits: u64,
    pub prefix_lookups: u64,
    pub hit_rate: f64,
}

/// Outcome of a mem-axis atom string (`hbm:<gb>` /
/// `multiturn:<turns>:<reuse_frac>` / `none`), the compact grammar the
/// scenario `mem` axis parses alongside the `env` axis grammar.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MemAxis {
    /// Uniform HBM capacity to enforce (activates the subsystem).
    pub hbm_gb: Option<f64>,
    /// Multi-turn workload transform: (turns per conversation,
    /// reused-prefix fraction of the prior context).
    pub multiturn: Option<(u32, f64)>,
}

impl MemAxis {
    /// Parse `+`-joined atoms, e.g. `"hbm:16"`,
    /// `"multiturn:4:0.6+hbm:32"`, or `"none"` (the inert label).
    pub fn parse_compact(s: &str) -> Result<MemAxis, String> {
        let s = s.trim();
        let mut axis = MemAxis::default();
        if s.is_empty() || s == "none" {
            return Ok(axis);
        }
        for atom in s.split('+') {
            let atom = atom.trim();
            let parts: Vec<&str> = atom.split(':').collect();
            match (parts[0], parts.len()) {
                ("hbm", 2) => {
                    if axis.hbm_gb.is_some() {
                        return Err(format!("duplicate hbm atom '{atom}'"));
                    }
                    let gb = parts[1]
                        .parse::<f64>()
                        .ok()
                        .filter(|&g| g > 0.0)
                        .ok_or_else(|| {
                            format!("hbm capacity '{}' must be a positive number", parts[1])
                        })?;
                    axis.hbm_gb = Some(gb);
                }
                ("multiturn", 3) => {
                    if axis.multiturn.is_some() {
                        return Err(format!("duplicate multiturn atom '{atom}'"));
                    }
                    let turns = parts[1]
                        .parse::<u32>()
                        .ok()
                        .filter(|&t| t >= 2)
                        .ok_or_else(|| {
                            format!("multiturn turns '{}' must be an integer >= 2", parts[1])
                        })?;
                    let reuse = parts[2]
                        .parse::<f64>()
                        .ok()
                        .filter(|f| (0.0..=1.0).contains(f))
                        .ok_or_else(|| {
                            format!("multiturn reuse_frac '{}' must be in [0, 1]", parts[2])
                        })?;
                    axis.multiturn = Some((turns, reuse));
                }
                _ => {
                    return Err(format!(
                        "unknown mem atom '{atom}' \
                         (none | hbm:<gb> | multiturn:<turns>:<reuse_frac>)"
                    ));
                }
            }
        }
        Ok(axis)
    }

    /// Does this axis cell change anything relative to the default?
    pub fn is_empty(&self) -> bool {
        *self == MemAxis::default()
    }
}

/// Runtime per-GPU KV pools. All hot-path methods early-return when
/// inactive so the no-`[mem]` configuration touches none of this state.
#[derive(Debug, Default)]
pub struct MemState {
    cfg: MemConfig,
    active: bool,
    /// Per-GPU HBM capacity in bytes; `None` = uncapped.
    cap: Vec<Option<u64>>,
    /// Bytes reserved by live decode contexts (never evictable).
    reserved: Vec<u64>,
    /// Bytes held by local cached (finished, idle) blocks.
    cached: Vec<u64>,
    /// Per-GPU LRU of local cached blocks (front = oldest = next victim).
    local: Vec<VecDeque<CachedBlock>>,
    /// Per-GPU remote/disk offload pools (demotion order preserved).
    remote: Vec<VecDeque<CachedBlock>>,
    remote_used: Vec<u64>,
    disk: Vec<VecDeque<CachedBlock>>,
    /// conversation id → (gpu, tier) of its cached block.
    conv_index: HashMap<u64, (usize, Tier)>,
    /// Decode stall deadline per GPU while demotions occupy the engines.
    pub evict_until: Vec<Micros>,
    /// Arrival-time prefix hits awaiting their prefill completion
    /// (request id → reused tokens) and publish (request id → tier
    /// fetch time to add to the KV transfer).
    pending_cached: HashMap<u64, u32>,
    pending_fetch: HashMap<u64, Micros>,
    evictions: u64,
    offload_bytes: u64,
    prefix_hits: u64,
    prefix_lookups: u64,
    peak_occ: f64,
}

impl MemState {
    /// Inert state for configs without a `[mem]` table (allocates
    /// nothing; every method is a guarded no-op).
    pub fn inactive() -> MemState {
        MemState::default()
    }

    /// Build the per-GPU pools. `hbm_of(gi)` is the slot's SKU capacity
    /// (GB); the uniform `cfg.hbm_gb` override wins when set.
    pub fn new(cfg: MemConfig, hbm_of: &[Option<f64>]) -> MemState {
        let n = hbm_of.len();
        let cap = hbm_of
            .iter()
            .map(|sku_gb| cfg.hbm_gb.or(*sku_gb).map(|gb| (gb * 1e9) as u64))
            .collect();
        MemState {
            cfg,
            active: true,
            cap,
            reserved: vec![0; n],
            cached: vec![0; n],
            local: vec![VecDeque::new(); n],
            remote: vec![VecDeque::new(); n],
            remote_used: vec![0; n],
            disk: vec![VecDeque::new(); n],
            conv_index: HashMap::new(),
            evict_until: vec![0; n],
            pending_cached: HashMap::new(),
            pending_fetch: HashMap::new(),
            evictions: 0,
            offload_bytes: 0,
            prefix_hits: 0,
            prefix_lookups: 0,
            peak_occ: 0.0,
        }
    }

    #[inline]
    pub fn active(&self) -> bool {
        self.active
    }

    pub fn config(&self) -> &MemConfig {
        &self.cfg
    }

    /// Is decode on `gi` stalled behind in-progress demotions?
    #[inline]
    pub fn stalled(&self, gi: usize, now: Micros) -> bool {
        self.active && now < self.evict_until[gi]
    }

    fn resident(&self, gi: usize) -> u64 {
        self.reserved[gi] + self.cached[gi]
    }

    /// HBM occupancy fraction of `gi` (0.0 when uncapped or inactive).
    pub fn occupancy(&self, gi: usize) -> f64 {
        if !self.active {
            return 0.0;
        }
        match self.cap[gi] {
            Some(cap) if cap > 0 => self.resident(gi) as f64 / cap as f64,
            _ => 0.0,
        }
    }

    /// Router memory-pressure term for decode GPU `gi`: occupancy
    /// scaled into request units so a near-full pool weighs like a
    /// near-full batch. Exactly `0.0` when inactive or uncapped, which
    /// keeps the comparator bit-identical to the pre-mem router.
    pub fn pressure(&self, gi: usize, max_decode_reqs: usize) -> f64 {
        if !self.active {
            return 0.0;
        }
        self.occupancy(gi) * max_decode_reqs as f64
    }

    /// Time to demote/fetch `bytes` through a tier's link.
    fn tier_time(&self, tier: Tier, bytes: u64) -> Micros {
        let (lat, bw_gbps) = match tier {
            Tier::Local => (0, self.cfg.local_bw_gbps),
            Tier::Remote => (self.cfg.remote_lat_us, self.cfg.remote_bw_gbps),
            Tier::Disk => (self.cfg.disk_lat_us, self.cfg.disk_bw_gbps),
        };
        // bytes / (GB/s) in us: bytes / (bw * 1e9) * 1e6.
        lat + (bytes as f64 / (bw_gbps * 1e3)) as Micros
    }

    /// Reserve `bytes` of HBM on `gi` for a decode context, demoting
    /// least-recently-finished cached blocks (local → remote while the
    /// remote tier has room, then → disk) until the reservation fits.
    /// Live reservations are never demoted — a victim mid-decode is
    /// structurally impossible — so `Err` means the GPU cannot host the
    /// context at all right now and the caller must backpressure.
    pub fn reserve(&mut self, gi: usize, bytes: u64) -> Result<Eviction, ()> {
        if !self.active {
            return Ok(Eviction::default());
        }
        let Some(cap) = self.cap[gi] else {
            self.reserved[gi] += bytes;
            return Ok(Eviction::default());
        };
        let mut ev = Eviction::default();
        while self.resident(gi) + bytes > cap {
            let Some(block) = self.local[gi].pop_front() else {
                // Nothing left to demote: reject. (Blocks already
                // demoted this call stay demoted — they are cached
                // copies, and re-promoting them would cost more.)
                return Err(());
            };
            self.cached[gi] -= block.bytes;
            let dest = if self.remote_used[gi] + block.bytes <= (self.cfg.remote_gb * 1e9) as u64 {
                self.remote_used[gi] += block.bytes;
                self.remote[gi].push_back(block);
                Tier::Remote
            } else {
                self.disk[gi].push_back(block);
                Tier::Disk
            };
            self.conv_index.insert(block.conv, (gi, dest));
            ev.time += self.tier_time(dest, block.bytes);
            ev.bytes += block.bytes;
            self.evictions += 1;
            self.offload_bytes += block.bytes;
        }
        self.reserved[gi] += bytes;
        Ok(ev)
    }

    /// Release a reservation (context finished without caching, moved
    /// to another GPU, or its GPU failed and re-dispatched).
    pub fn release(&mut self, gi: usize, bytes: u64) {
        if !self.active {
            return;
        }
        debug_assert!(self.reserved[gi] >= bytes, "release exceeds reservation");
        self.reserved[gi] = self.reserved[gi].saturating_sub(bytes);
    }

    /// A context finished on `gi`: convert its reservation into a
    /// prefix-cache block for conversation `conv` (resident bytes are
    /// unchanged, so the capacity invariant is untouched). With the
    /// prefix cache disabled this is a plain release.
    pub fn finish(&mut self, gi: usize, conv: Option<u64>, bytes: u64, tokens: u32) {
        if !self.active {
            return;
        }
        let conv = match conv {
            Some(c) if self.cfg.prefix_cache => c,
            _ => {
                self.release(gi, bytes);
                return;
            }
        };
        // A stale block from an earlier turn (that never got consumed)
        // is superseded by this longer context.
        self.consume_block(conv);
        self.release(gi, bytes);
        self.cached[gi] += bytes;
        self.local[gi].push_back(CachedBlock { conv, bytes, tokens });
        self.conv_index.insert(conv, (gi, Tier::Local));
    }

    /// Remove and return `conv`'s cached block wherever it lives.
    fn consume_block(&mut self, conv: u64) -> Option<(usize, Tier, CachedBlock)> {
        let (gi, tier) = self.conv_index.remove(&conv)?;
        let pool = match tier {
            Tier::Local => &mut self.local[gi],
            Tier::Remote => &mut self.remote[gi],
            Tier::Disk => &mut self.disk[gi],
        };
        let at = pool.iter().position(|b| b.conv == conv)?;
        let block = pool.remove(at).unwrap();
        match tier {
            Tier::Local => self.cached[gi] -= block.bytes,
            Tier::Remote => self.remote_used[gi] -= block.bytes,
            Tier::Disk => {}
        }
        Some((gi, tier, block))
    }

    /// Arrival-time prefix lookup for a multi-turn request: on a hit the
    /// cached block is consumed and the caller shrinks the prompt by the
    /// returned token count; the tier fetch time is parked for the
    /// publish path (`take_fetch`). `input_tokens` is the full prompt —
    /// at least one token always remains to prefill.
    pub fn prefix_lookup(
        &mut self,
        req_id: u64,
        conv: u64,
        prefix_tokens: u32,
        input_tokens: u32,
        bytes_per_token: u64,
    ) -> Option<u32> {
        if !self.active || !self.cfg.prefix_cache || prefix_tokens == 0 {
            return None;
        }
        self.prefix_lookups += 1;
        let (_, tier, block) = self.consume_block(conv)?;
        let tokens = prefix_tokens
            .min(block.tokens)
            .min(input_tokens.saturating_sub(1));
        if tokens == 0 {
            return None;
        }
        self.prefix_hits += 1;
        let fetch = self.tier_time(tier, tokens as u64 * bytes_per_token);
        self.pending_cached.insert(req_id, tokens);
        self.pending_fetch.insert(req_id, fetch);
        Some(tokens)
    }

    /// Reused-prefix tokens of a request whose prefill just completed
    /// (consumed into `ReqState::cached_tokens`).
    pub fn take_cached_tokens(&mut self, req_id: u64) -> u32 {
        if !self.active {
            return 0;
        }
        self.pending_cached.remove(&req_id).unwrap_or(0)
    }

    /// Tier fetch time owed by a prefix hit, paid on the KV publish.
    pub fn take_fetch(&mut self, req_id: u64) -> Micros {
        if !self.active {
            return 0;
        }
        self.pending_fetch.remove(&req_id).unwrap_or(0)
    }

    /// GPU `gi` failed: its HBM contents (reservations and every cached
    /// block in all tiers — the offload pools hang off its node agent)
    /// are gone. In-flight decode items re-reserve on their new target.
    pub fn invalidate_gpu(&mut self, gi: usize) {
        if !self.active {
            return;
        }
        self.reserved[gi] = 0;
        self.cached[gi] = 0;
        self.remote_used[gi] = 0;
        self.evict_until[gi] = 0;
        for pool in [&mut self.local[gi], &mut self.remote[gi], &mut self.disk[gi]] {
            for b in pool.drain(..) {
                self.conv_index.remove(&b.conv);
            }
        }
    }

    /// Record one occupancy sample; returns the fleet max fraction (the
    /// `mem_trace` series the ShapeCheck walks).
    pub fn sample_occupancy(&mut self) -> f64 {
        let max = (0..self.cap.len())
            .map(|gi| self.occupancy(gi))
            .fold(0.0f64, f64::max);
        if max > self.peak_occ {
            self.peak_occ = max;
        }
        max
    }

    /// Fold the counters into the run summary.
    pub fn summary(&self) -> MemSummary {
        MemSummary {
            peak_occupancy: self.peak_occ,
            evictions: self.evictions,
            offload_bytes: self.offload_bytes,
            prefix_hits: self.prefix_hits,
            prefix_lookups: self.prefix_lookups,
            hit_rate: if self.prefix_lookups > 0 {
                self.prefix_hits as f64 / self.prefix_lookups as f64
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(hbm_gb: f64, n: usize) -> MemState {
        let cfg = MemConfig { hbm_gb: Some(hbm_gb), ..MemConfig::default() };
        MemState::new(cfg, &vec![None; n])
    }

    #[test]
    fn config_validation() {
        MemConfig::default().validate().unwrap();
        let bad = MemConfig { hbm_gb: Some(0.0), ..MemConfig::default() };
        assert!(bad.validate().is_err());
        let bad = MemConfig { remote_gb: -1.0, ..MemConfig::default() };
        assert!(bad.validate().is_err());
        let bad = MemConfig { disk_bw_gbps: 0.0, ..MemConfig::default() };
        assert!(bad.validate().is_err());
        // Tier ordering: remote faster than local is structural nonsense.
        let bad = MemConfig { remote_bw_gbps: 128.0, ..MemConfig::default() };
        assert!(bad.validate().is_err(), "local >= remote must hold");
        let bad = MemConfig { disk_bw_gbps: 32.0, ..MemConfig::default() };
        assert!(bad.validate().is_err(), "remote >= disk must hold");
    }

    #[test]
    fn inactive_state_is_inert() {
        let mut m = MemState::inactive();
        assert!(!m.active());
        assert_eq!(m.pressure(0, 64), 0.0);
        assert_eq!(m.occupancy(0), 0.0);
        assert!(!m.stalled(0, 100));
        let ev = m.reserve(0, u64::MAX).unwrap();
        assert_eq!(ev.bytes, 0);
        m.release(0, 123);
        m.finish(0, Some(1), 123, 10);
        m.invalidate_gpu(0);
        assert_eq!(m.summary(), MemSummary::default());
    }

    #[test]
    fn pool_exactly_full_admits_then_rejects() {
        let mut m = pool(1.0, 1); // 1 GB = 1e9 bytes
        assert!(m.reserve(0, 600_000_000).unwrap().bytes == 0);
        // Exactly to the byte: still admitted, occupancy hits 1.0.
        assert!(m.reserve(0, 400_000_000).is_ok());
        assert!((m.occupancy(0) - 1.0).abs() < 1e-12);
        // One more byte has no victim to evict: rejected.
        assert!(m.reserve(0, 1).is_err());
        m.release(0, 400_000_000);
        assert!(m.reserve(0, 1).is_ok());
    }

    #[test]
    fn eviction_demotes_lru_and_never_touches_reservations() {
        let mut m = pool(1.0, 1);
        m.reserve(0, 500_000_000).unwrap();
        // Two finished conversations park as cached blocks (LRU: 7 older).
        m.finish(0, Some(7), 300_000_000, 2000);
        m.finish(0, Some(8), 200_000_000, 1500);
        m.release(0, 0);
        assert!((m.occupancy(0) - 1.0).abs() < 1e-12);
        // A 250 MB reservation must demote conv 7 (oldest) only.
        let ev = m.reserve(0, 250_000_000).unwrap();
        assert_eq!(ev.bytes, 300_000_000);
        assert!(ev.time > 0);
        assert_eq!(m.conv_index.get(&7), Some(&(0, Tier::Remote)));
        assert_eq!(m.conv_index.get(&8), Some(&(0, Tier::Local)));
        let s = m.summary();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.offload_bytes, 300_000_000);
        // Mid-decode victims are impossible: with only reservations
        // left, further pressure rejects instead of evicting them.
        m.reserve(0, 200_000_000).unwrap(); // demotes conv 8
        assert!(m.reserve(0, 100_000_000).is_err());
        assert_eq!(m.reserved[0], 950_000_000, "reservations intact");
    }

    #[test]
    fn remote_overflow_spills_to_disk() {
        let cfg = MemConfig {
            hbm_gb: Some(1.0),
            remote_gb: 0.25, // 250 MB remote tier
            ..MemConfig::default()
        };
        let mut m = MemState::new(cfg, &[None]);
        m.finish(0, Some(1), 200_000_000, 100);
        m.finish(0, Some(2), 300_000_000, 100);
        m.finish(0, Some(3), 500_000_000, 100);
        // Reserve the whole pool: all three demote; 1 fits remote,
        // 2 and 3 overflow to disk.
        let ev = m.reserve(0, 1_000_000_000).unwrap();
        assert_eq!(ev.bytes, 1_000_000_000);
        assert_eq!(m.conv_index.get(&1), Some(&(0, Tier::Remote)));
        assert_eq!(m.conv_index.get(&2), Some(&(0, Tier::Disk)));
        assert_eq!(m.conv_index.get(&3), Some(&(0, Tier::Disk)));
        // Disk demotions are slower than remote ones.
        let remote_t = m.tier_time(Tier::Remote, 100_000_000);
        let disk_t = m.tier_time(Tier::Disk, 100_000_000);
        assert!(disk_t > remote_t);
        assert!(m.tier_time(Tier::Local, 100_000_000) < remote_t);
    }

    #[test]
    fn prefix_cache_hit_consumes_block_and_charges_tier_fetch() {
        let mut m = pool(4.0, 2);
        m.reserve(1, 400_000_000).unwrap();
        m.finish(1, Some(42), 400_000_000, 3000);
        // Next turn of conv 42: 2000-token reusable prefix, 2500 prompt.
        let hit = m.prefix_lookup(9, 42, 2000, 2500, 131_072);
        assert_eq!(hit, Some(2000));
        assert_eq!(m.take_cached_tokens(9), 2000);
        assert!(m.take_fetch(9) > 0, "local fetch pays bandwidth");
        // The block is consumed: a second lookup misses.
        assert_eq!(m.prefix_lookup(10, 42, 2000, 2500, 131_072), None);
        let s = m.summary();
        assert_eq!((s.prefix_hits, s.prefix_lookups), (1, 2));
        assert!((s.hit_rate - 0.5).abs() < 1e-12);
        // Consuming freed the cached bytes.
        assert_eq!(m.cached[1], 0);
    }

    #[test]
    fn prefix_hit_never_zeroes_the_prompt() {
        let mut m = pool(4.0, 1);
        m.finish(0, Some(5), 100_000_000, 4000);
        // Prefix covers the whole 1000-token prompt: one token remains.
        assert_eq!(m.prefix_lookup(1, 5, 4000, 1000, 131_072), Some(999));
    }

    #[test]
    fn prefix_cache_disabled_means_plain_release() {
        let cfg = MemConfig {
            hbm_gb: Some(1.0),
            prefix_cache: false,
            ..MemConfig::default()
        };
        let mut m = MemState::new(cfg, &[None]);
        m.reserve(0, 500_000_000).unwrap();
        m.finish(0, Some(3), 500_000_000, 100);
        assert_eq!(m.resident(0), 0, "finish released instead of caching");
        assert_eq!(m.prefix_lookup(1, 3, 100, 200, 131_072), None);
        assert_eq!(m.summary().prefix_lookups, 0);
    }

    #[test]
    fn gpu_failure_invalidates_prefix_blocks_and_reservations() {
        let mut m = pool(1.0, 2);
        m.reserve(0, 300_000_000).unwrap();
        m.finish(0, Some(11), 300_000_000, 500);
        m.finish(0, Some(12), 600_000_000, 500);
        // Force 11 to the remote tier so a non-local block dies too.
        m.reserve(0, 500_000_000).unwrap();
        assert_eq!(m.conv_index.get(&11), Some(&(0, Tier::Remote)));
        m.invalidate_gpu(0);
        assert_eq!(m.resident(0), 0);
        assert_eq!(m.prefix_lookup(1, 11, 100, 200, 131_072), None);
        assert_eq!(m.prefix_lookup(2, 12, 100, 200, 131_072), None);
        // Blocks on the surviving GPU are untouched.
        m.finish(1, Some(13), 100_000_000, 500);
        assert!(m.prefix_lookup(3, 13, 100, 200, 131_072).is_some());
    }

    #[test]
    fn sku_capacity_applies_per_slot_with_uniform_override_winning() {
        let cfg = MemConfig::default(); // hbm_gb unset
        let m = MemState::new(cfg, &[Some(2.0), None]);
        assert_eq!(m.cap[0], Some(2_000_000_000));
        assert_eq!(m.cap[1], None, "slot without SKU capacity is uncapped");
        let cfg = MemConfig { hbm_gb: Some(1.0), ..MemConfig::default() };
        let m = MemState::new(cfg, &[Some(2.0), None]);
        assert_eq!(m.cap[0], Some(1_000_000_000), "uniform override wins");
        assert_eq!(m.cap[1], Some(1_000_000_000));
    }

    #[test]
    fn pressure_scales_occupancy_into_request_units() {
        let mut m = pool(1.0, 1);
        assert_eq!(m.pressure(0, 64), 0.0);
        m.reserve(0, 500_000_000).unwrap();
        assert!((m.pressure(0, 64) - 32.0).abs() < 1e-9);
        assert!((m.sample_occupancy() - 0.5).abs() < 1e-9);
        assert!((m.summary().peak_occupancy - 0.5).abs() < 1e-9);
    }

    #[test]
    fn axis_grammar_round_trips() {
        assert!(MemAxis::parse_compact("none").unwrap().is_empty());
        assert!(MemAxis::parse_compact("").unwrap().is_empty());
        let a = MemAxis::parse_compact("hbm:16").unwrap();
        assert_eq!(a.hbm_gb, Some(16.0));
        assert_eq!(a.multiturn, None);
        let a = MemAxis::parse_compact("multiturn:4:0.6+hbm:32").unwrap();
        assert_eq!(a.hbm_gb, Some(32.0));
        assert_eq!(a.multiturn, Some((4, 0.6)));
        assert!(MemAxis::parse_compact("hbm:0").is_err());
        assert!(MemAxis::parse_compact("hbm:x").is_err());
        assert!(MemAxis::parse_compact("multiturn:1:0.5").is_err());
        assert!(MemAxis::parse_compact("multiturn:4:1.5").is_err());
        assert!(MemAxis::parse_compact("hbm:8+hbm:16").is_err());
        assert!(MemAxis::parse_compact("warp:9").is_err());
    }
}
