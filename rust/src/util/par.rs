//! Work-stealing fan-out for independent sweep points.
//!
//! Moved here from `experiments` so the `scenario` layer (and anything
//! else below the experiment drivers) can fan work without a layering
//! cycle; `experiments` re-exports these names for compatibility.

/// Worker threads for sweep fan-out with an explicit override: a
/// caller-supplied count (e.g. a `--threads` CLI flag) always wins,
/// then the `RAPID_SWEEP_THREADS` env var, then the machine's
/// parallelism. `1` forces serial execution (useful for timing
/// baselines — see `benches/sweep_parallel.rs`).
pub fn sweep_threads_with(explicit: Option<usize>) -> usize {
    explicit
        .filter(|&n| n >= 1)
        .or_else(|| {
            std::env::var("RAPID_SWEEP_THREADS")
                .ok()
                .and_then(|s| s.parse().ok())
                .filter(|&n| n >= 1)
        })
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Default worker-thread count (no explicit override).
pub fn sweep_threads() -> usize {
    sweep_threads_with(None)
}

/// Fan `f` over `items` across worker threads (work-stealing via a
/// shared atomic cursor), preserving input order in the output.
///
/// This is the sweep runner every Study cell, figure driver, bench and
/// the `rapid sweep`/`rapid study` CLI go through: each point is an
/// independent deterministic simulation (seeded RNGs, no shared state),
/// so results are bit-identical to a serial run regardless of thread
/// count. Implemented on `std::thread::scope` — no external dependency.
pub fn parallel_map_threads<T, R, F>(items: &[T], threads: Option<usize>, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = sweep_threads_with(threads).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let done: std::sync::Mutex<Vec<(usize, R)>> =
        std::sync::Mutex::new(Vec::with_capacity(items.len()));
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                done.lock().unwrap().push((i, r));
            });
        }
    });
    let mut out = done.into_inner().unwrap();
    out.sort_by_key(|&(i, _)| i);
    out.into_iter().map(|(_, r)| r).collect()
}

/// [`parallel_map_threads`] with the default thread count.
pub fn parallel_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_threads(items, None, f)
}

/// [`parallel_map_threads`] with a completion callback: `on_done(done,
/// total)` fires after each item finishes (from whichever worker thread
/// finished it; `done` is the monotone completion count, not an index).
/// Results stay bit-identical to the plain variant — the callback only
/// observes progress, it never orders work.
pub fn parallel_map_threads_progress<T, R, F, P>(
    items: &[T],
    threads: Option<usize>,
    f: F,
    on_done: P,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
    P: Fn(usize, usize) + Sync,
{
    let total = items.len();
    let threads = sweep_threads_with(threads).min(total.max(1));
    if threads <= 1 || total <= 1 {
        return items
            .iter()
            .enumerate()
            .map(|(i, x)| {
                let r = f(x);
                on_done(i + 1, total);
                r
            })
            .collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let completed = std::sync::atomic::AtomicUsize::new(0);
    let done: std::sync::Mutex<Vec<(usize, R)>> =
        std::sync::Mutex::new(Vec::with_capacity(total));
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= total {
                    break;
                }
                let r = f(&items[i]);
                done.lock().unwrap().push((i, r));
                let n = completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
                on_done(n, total);
            });
        }
    });
    let mut out = done.into_inner().unwrap();
    out.sort_by_key(|&(i, _)| i);
    out.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order_and_coverage() {
        let items: Vec<u64> = (0..57).collect();
        let out = parallel_map(&items, |&x| x * 3);
        assert_eq!(out, items.iter().map(|&x| x * 3).collect::<Vec<_>>());
        let empty: Vec<u64> = Vec::new();
        assert!(parallel_map(&empty, |&x: &u64| x).is_empty());
        assert_eq!(parallel_map(&[9u64], |&x| x + 1), vec![10]);
    }

    #[test]
    fn explicit_thread_count_wins_over_env() {
        // The env var may or may not be set in this process; an explicit
        // count must win either way, and results never depend on it.
        assert_eq!(sweep_threads_with(Some(2)), 2);
        assert_eq!(sweep_threads_with(Some(1)), 1);
        // 0 is "no override", falling through to env/default.
        assert!(sweep_threads_with(Some(0).filter(|&n| n >= 1)) >= 1);
    }

    #[test]
    fn results_identical_across_thread_counts() {
        let items: Vec<u64> = (0..40).collect();
        let serial = parallel_map_threads(&items, Some(1), |&x| x * x + 1);
        let par = parallel_map_threads(&items, Some(8), |&x| x * x + 1);
        assert_eq!(serial, par);
    }

    #[test]
    fn progress_callback_counts_every_completion() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let items: Vec<u64> = (0..23).collect();
        for threads in [1, 4] {
            let fired = AtomicUsize::new(0);
            let max_seen = AtomicUsize::new(0);
            let out = parallel_map_threads_progress(
                &items,
                Some(threads),
                |&x| x + 7,
                |done, total| {
                    assert_eq!(total, 23);
                    assert!(done >= 1 && done <= total);
                    fired.fetch_add(1, Ordering::Relaxed);
                    max_seen.fetch_max(done, Ordering::Relaxed);
                },
            );
            assert_eq!(out, items.iter().map(|&x| x + 7).collect::<Vec<_>>());
            assert_eq!(fired.load(Ordering::Relaxed), 23);
            assert_eq!(max_seen.load(Ordering::Relaxed), 23);
        }
    }
}
