//! The L3 coordination layer — the paper's system contribution.
//!
//! * [`router`] — central request routing across worker pools;
//! * [`batcher`] — per-GPU local scheduling (prefill batches, continuous
//!   decode batching, chunked prefill for the coalesced baseline);
//! * [`dynamic`] — Algorithm 1, the reactive power/GPU reallocation
//!   controller.
//!
//! The same policy code drives both the discrete-event simulator
//! ([`crate::sim`]) and the real PJRT serving path ([`crate::server`]).

pub mod batcher;
pub mod dynamic;
pub mod router;

pub use dynamic::{Action, Controller, Snapshot};
