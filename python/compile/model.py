"""Layer-2 JAX model: a mini-Llama forward pass built on the L1 kernels.

This is the compute graph the rust coordinator serves. It is a faithful
small-scale Llama-3 architecture (RMSNorm, RoPE, SwiGLU, causal MHA) with
two entry points matching the disaggregated serving split:

  * `prefill(params, tokens, lens)`   -> (next-token logits, kv caches)
  * `decode(params, token, pos, kv)`  -> (logits, updated kv caches)

Both call the Pallas kernels in `kernels/attention.py` so the kernels lower
into the same HLO module that `aot.py` exports for the rust runtime.

Cache-slot protocol (shared with the rust engine, see DESIGN.md):
prompts are right-padded to the compiled prefill length `S`; prefill writes
cache slots `[0, S)` (slots >= len contain garbage K/V that causal masking
keeps unreachable); decode writes slot `pos` then attends to `<= pos`, so
garbage slots are overwritten exactly one step before they become visible.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention as K
from .kernels import ref as ref_k


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (mini-Llama defaults)."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 704
    max_seq: int = 256
    prefill_seq: int = 128
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Flat, ordered parameter list — the AOT calling convention.

        The rust runtime feeds weights positionally in exactly this order;
        `aot.py` records it in the manifest.
        """
        d, f, v = self.d_model, self.d_ff, self.vocab
        specs: List[Tuple[str, Tuple[int, ...]]] = [("embed", (v, d))]
        for l in range(self.n_layers):
            specs += [
                (f"layer{l}.attn_norm", (d,)),
                (f"layer{l}.wq", (d, d)),
                (f"layer{l}.wk", (d, d)),
                (f"layer{l}.wv", (d, d)),
                (f"layer{l}.wo", (d, d)),
                (f"layer{l}.ffn_norm", (d,)),
                (f"layer{l}.w_gate", (d, f)),
                (f"layer{l}.w_up", (d, f)),
                (f"layer{l}.w_down", (f, d)),
            ]
        specs += [("final_norm", (d,)), ("lm_head", (d, v))]
        return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic scaled-normal init (the repo's fixed test model)."""
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jax.Array] = {}
    for i, (name, shape) in enumerate(cfg.param_specs()):
        k = jax.random.fold_in(key, i)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            params[name] = (
                jax.random.normal(k, shape, jnp.float32) * (fan_in**-0.5)
            )
    return params


def _rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, head_dim), positions: (..., seq)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    ).astype(x.dtype)


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, D) -> (B, H, S, Dh)."""
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    """(B, H, S, Dh) -> (B, S, D)."""
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def prefill(
    cfg: ModelConfig, params: Dict[str, jax.Array], tokens: jax.Array, lens: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Process a padded prompt batch; return first-token logits + KV caches.

    Args:
      tokens: i32[B, S] right-padded prompts (S == cfg.prefill_seq).
      lens:   i32[B] true prompt lengths (1 <= len <= S).

    Returns:
      logits:  f32[B, vocab] at position len-1 (the first generated token).
      k_cache: f32[L, B, H, max_seq, Dh] (slots [0, S) written).
      v_cache: same shape.
    """
    b, s = tokens.shape
    h, dh = cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens]  # (B, S, D)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    k_layers, v_layers = [], []
    for l in range(cfg.n_layers):
        p = lambda n: params[f"layer{l}.{n}"]
        xn = ref_k.rmsnorm(x, p("attn_norm"), cfg.norm_eps)
        q = _split_heads(xn @ p("wq"), h)
        k = _split_heads(xn @ p("wk"), h)
        v = _split_heads(xn @ p("wv"), h)
        q = _rope(q, positions[:, None, :], cfg.rope_theta)
        k = _rope(k, positions[:, None, :], cfg.rope_theta)
        attn = K.prefill_attention(q, k, v)  # (B, H, S, Dh)
        x = x + _merge_heads(attn) @ p("wo")
        xn = ref_k.rmsnorm(x, p("ffn_norm"), cfg.norm_eps)
        ff = K.swiglu_ffn(
            xn.reshape(b * s, cfg.d_model), p("w_gate"), p("w_up"), p("w_down")
        ).reshape(b, s, cfg.d_model)
        x = x + ff
        # Cache slots beyond S stay zero; causal masking keeps them dead.
        pad = cfg.max_seq - s
        k_layers.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))))
        v_layers.append(jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))))

    x = ref_k.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    last = jnp.take_along_axis(x, (lens - 1)[:, None, None], axis=1)[:, 0]  # (B, D)
    logits = last @ params["lm_head"]
    return logits, jnp.stack(k_layers), jnp.stack(v_layers)


def decode(
    cfg: ModelConfig,
    params: Dict[str, jax.Array],
    token: jax.Array,
    pos: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step for a batch of sequences.

    Args:
      token: i32[B] current token ids (slot `pos`).
      pos:   i32[B] cache slot of `token` (== generated-so-far + len - 1 + 1).
      k_cache, v_cache: f32[L, B, H, max_seq, Dh].

    Returns:
      (logits f32[B, vocab], updated k_cache, updated v_cache)
    """
    b = token.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim
    x = params["embed"][token]  # (B, D)

    new_k, new_v = [], []
    for l in range(cfg.n_layers):
        p = lambda n: params[f"layer{l}.{n}"]
        xn = ref_k.rmsnorm(x, p("attn_norm"), cfg.norm_eps)
        q = (xn @ p("wq")).reshape(b, h, dh)
        k = (xn @ p("wk")).reshape(b, h, dh)
        v = (xn @ p("wv")).reshape(b, h, dh)
        q = _rope(q, pos[:, None], cfg.rope_theta)
        k = _rope(k, pos[:, None], cfg.rope_theta)

        # Write slot `pos` per batch element, then attend to slots <= pos.
        def write(cache_bh, val_bh, p_b):
            # cache_bh: (H, max_seq, Dh), val_bh: (H, Dh)
            return jax.lax.dynamic_update_slice(
                cache_bh, val_bh[:, None, :], (0, p_b, 0)
            )

        kc = jax.vmap(write)(k_cache[l], k, pos)
        vc = jax.vmap(write)(v_cache[l], v, pos)
        new_k.append(kc)
        new_v.append(vc)

        attn = K.decode_attention(q, kc, vc, pos)  # (B, H, Dh)
        x = x + attn.reshape(b, h * dh) @ p("wo")
        xn = ref_k.rmsnorm(x, p("ffn_norm"), cfg.norm_eps)
        x = x + K.swiglu_ffn(xn, p("w_gate"), p("w_up"), p("w_down"), block_rows=b)

    x = ref_k.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def full_forward(
    cfg: ModelConfig, params: Dict[str, jax.Array], tokens: jax.Array
) -> jax.Array:
    """Reference: plain causal forward over the whole sequence (no cache).

    Used by tests to validate the prefill+decode cache protocol: logits at
    position t here must match prefill-then-decode logits.
    Uses only ref.py math (no Pallas) so it is an independent oracle.
    """
    b, s = tokens.shape
    h = cfg.n_heads
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    for l in range(cfg.n_layers):
        p = lambda n: params[f"layer{l}.{n}"]
        xn = ref_k.rmsnorm(x, p("attn_norm"), cfg.norm_eps)
        q = _rope(_split_heads(xn @ p("wq"), h), positions[:, None, :], cfg.rope_theta)
        k = _rope(_split_heads(xn @ p("wk"), h), positions[:, None, :], cfg.rope_theta)
        v = _split_heads(xn @ p("wv"), h)
        attn = ref_k.attention_prefill(q, k, v)
        x = x + _merge_heads(attn) @ p("wo")
        xn = ref_k.rmsnorm(x, p("ffn_norm"), cfg.norm_eps)
        x = x + ref_k.swiglu_ffn(
            xn.reshape(b * s, cfg.d_model), p("w_gate"), p("w_up"), p("w_down")
        ).reshape(b, s, cfg.d_model)
    x = ref_k.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"]  # (B, S, vocab)
