//! Workload generation: statistical replicas of the paper's datasets.
//!
//! The coordinator only observes `(arrival, input_tokens, output_tokens)`,
//! so a dataset is reproduced by matching those marginals:
//!
//! * [`longbench`] — long-tailed prompt lengths capped at 8 K tokens with
//!   modest outputs (paper §4: "LongBench … maximum of 8K input tokens");
//! * [`sonnet`] — fixed-size prompts/outputs for controlled experiments
//!   (8K/128 prefill-heavy, 512/512 decode-heavy), including the Fig 8/9
//!   two-phase mixed trace;
//! * [`arrivals`] — Poisson arrival processes plus a bursty variant.

pub mod arrivals;
pub mod longbench;
pub mod sonnet;
pub mod trace;
pub mod tracespec;

pub use arrivals::{ArrivalProcess, Burstiness};
pub use trace::{ConvTurn, Trace};

use crate::types::{Micros, Request, RequestId, Slo};

/// Anything that can produce the token-size profile of request `i`.
pub trait SizeSampler {
    /// (input_tokens, output_tokens) for the i-th request.
    fn sample(&mut self, i: usize) -> (u32, u32);
}

/// Assemble a full trace from an arrival process + size sampler + SLO.
pub fn build_trace<S: SizeSampler>(
    n: usize,
    arrivals: &mut ArrivalProcess,
    sizes: &mut S,
    slo: Slo,
) -> Trace {
    let mut requests = Vec::with_capacity(n);
    let mut t: Micros = 0;
    for i in 0..n {
        t = arrivals.next_after(t);
        let (input_tokens, output_tokens) = sizes.sample(i);
        requests.push(Request {
            id: RequestId(i as u64),
            arrival: t,
            input_tokens,
            output_tokens,
            slo,
            tenant: 0,
        });
    }
    Trace { requests, ..Trace::default() }
}

/// Fold a single-turn trace into multi-turn conversations in place
/// (the scenario `multiturn:<turns>:<reuse_frac>` knob).
///
/// Requests keep their arrival times and ids; request `i` joins
/// conversation `i % n_convs` (interleaved, so a conversation's turns
/// are spread across the trace and the prior turn has finished before
/// the next arrives). Each turn after a conversation's first re-sends
/// `reuse_frac` of the conversation's accumulated context as a
/// reusable prefix: those tokens are *added* to the request's prompt —
/// without a prefix cache they must be re-prefilled, with one they are
/// fetched from the cached block instead.
pub fn make_multiturn(trace: &mut Trace, turns: u32, reuse_frac: f64) {
    if turns <= 1 || trace.requests.is_empty() {
        return;
    }
    let n = trace.requests.len();
    let n_convs = (n / turns as usize).max(1);
    let mut ctx_tokens: Vec<u64> = vec![0; n_convs];
    trace.conv.clear();
    for (i, r) in trace.requests.iter_mut().enumerate() {
        let conv = (i % n_convs) as u64;
        let prefix = (ctx_tokens[conv as usize] as f64 * reuse_frac) as u32;
        r.input_tokens += prefix;
        ctx_tokens[conv as usize] += (r.input_tokens + r.output_tokens) as u64;
        trace.conv.push(ConvTurn { req_id: r.id.0, conv, prefix_tokens: prefix });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    struct Fixed;
    impl SizeSampler for Fixed {
        fn sample(&mut self, _i: usize) -> (u32, u32) {
            (100, 10)
        }
    }

    #[test]
    fn multiturn_interleaves_conversations_and_grows_prefixes() {
        let mut ap = ArrivalProcess::poisson(Rng::new(5), 10.0);
        let mut trace = build_trace(12, &mut ap, &mut Fixed, Slo::paper_default());
        make_multiturn(&mut trace, 4, 0.5);
        assert_eq!(trace.conv.len(), 12);
        // 12 requests / 4 turns = 3 conversations, interleaved i % 3.
        for (i, c) in trace.conv.iter().enumerate() {
            assert_eq!(c.conv, (i % 3) as u64);
            assert_eq!(c.req_id, trace.requests[i].id.0);
        }
        // First turns send the plain prompt; later turns add a prefix.
        assert_eq!(trace.conv[0].prefix_tokens, 0);
        assert_eq!(trace.requests[0].input_tokens, 100);
        // Turn 2 of conv 0 (index 3): prefix = 0.5 * (100 + 10) = 55.
        assert_eq!(trace.conv[3].prefix_tokens, 55);
        assert_eq!(trace.requests[3].input_tokens, 155);
        // Prefixes grow with accumulated context.
        assert!(trace.conv[6].prefix_tokens > trace.conv[3].prefix_tokens);
        // turns <= 1 is a no-op.
        let mut ap = ArrivalProcess::poisson(Rng::new(5), 10.0);
        let mut t1 = build_trace(12, &mut ap, &mut Fixed, Slo::paper_default());
        make_multiturn(&mut t1, 1, 0.5);
        assert!(t1.conv.is_empty());
        assert_eq!(t1.requests[3].input_tokens, 100);
    }

    #[test]
    fn build_trace_monotone_arrivals_and_ids() {
        let mut ap = ArrivalProcess::poisson(Rng::new(1), 10.0);
        let trace = build_trace(100, &mut ap, &mut Fixed, Slo::paper_default());
        assert_eq!(trace.requests.len(), 100);
        for w in trace.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
            assert!(w[0].id < w[1].id);
        }
    }
}
