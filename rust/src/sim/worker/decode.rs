//! Decode worker behavior: continuous batching with admissions at step
//! boundaries, plus KV-arrival ingestion (paper §3.2).

use crate::cluster::Cluster;
use crate::coordinator::batcher;
use crate::sim::event::Event;
use crate::sim::worker::RoleBehavior;
use crate::types::{GpuId, Role};
use crate::util::slab::SlotId;

pub struct DecodeBehavior;

impl RoleBehavior for DecodeBehavior {
    fn role(&self) -> Role {
        Role::Decode
    }

    fn kick(&self, cl: &mut Cluster, gi: usize) {
        cl.kick_decode(gi);
    }

    fn on_step_done(&self, cl: &mut Cluster, gi: usize, epoch: u64) {
        cl.on_decode_step(gi, epoch);
    }
}

impl Cluster {
    /// A KV transfer landed: ingest, release the producing node's ring
    /// slot, and let stalled prefill GPUs publish again.
    pub(crate) fn on_kv_arrive(&mut self, gi: usize, src_node: usize, slot: SlotId) {
        self.ring_used[src_node] = self.ring_used[src_node].saturating_sub(1);
        // Re-transfers deferred on a full ring go out first, FIFO, as
        // soon as a slot frees (deterministic backpressure; strictly a
        // no-op while the wait queue is empty).
        while self.ring_free(src_node) > 0 {
            let Some((via, s)) = self.retransfer_wait[src_node].pop_front() else {
                break;
            };
            self.redispatch_decode(via, src_node, None, s);
        }
        if self.gpus[gi].failed {
            // The target died while the KV was in flight: re-fetch to a
            // surviving worker (conservation: the request is never lost).
            self.redispatch_decode(gi, src_node, Some(gi), slot);
            return;
        }
        self.gpus[gi].dec_pending.push_back(slot);
        if let Some(o) = self.obs.as_deref_mut() {
            let req = self.store.get(slot).req.id.0;
            o.record(crate::obs::ObsEvent::KvArrive { at: self.now, req, gpu: gi });
        }
        self.reindex(gi); // occupancy grew: update before any publish picks
        // A slot freed: stalled prefill GPUs may publish now. Only live
        // prefill-role workers can hold publish_wait items (they drain
        // before any role flip and are flushed on failure), so walking
        // the maintained role list visits every candidate.
        let mut k = 0;
        while k < self.prefill_ids.len() {
            let i = self.prefill_ids[k];
            if !self.gpus[i].publish_wait.is_empty() {
                self.try_publish(i);
                self.kick_prefill(i);
            }
            k += 1;
        }
        // Role-dispatched: on the coalesced topology the KV target is a
        // coalesced worker (failure re-dispatch), not a decode worker.
        let role = self.gpus[gi].role;
        crate::sim::worker::behavior(role).kick(self, gi);
    }

    /// Start the next decode step if possible, then re-sync the hot
    /// mirror: admissions and preemption swaps move slots between
    /// pending and active without passing through `reindex` (the total
    /// decode load is unchanged), but the tick-rate readers see the
    /// split counts.
    pub(crate) fn kick_decode(&mut self, gi: usize) {
        self.kick_decode_inner(gi);
        self.sync_hot(gi);
    }

    fn kick_decode_inner(&mut self, gi: usize) {
        // In-progress KV demotions occupy the copy engines: the next
        // step waits out the eviction stall (a MemEvict event resumes).
        if self.mem.stalled(gi, self.now) {
            return;
        }
        let store = &self.store;
        let g = &mut self.gpus[gi];
        if g.busy || g.failed || g.role != Role::Decode {
            return;
        }
        // Admissions at step boundaries (continuous batching). Draining
        // GPUs stop admitting.
        let mut admitted = 0usize;
        let mut preempted: Option<(u64, u64, u8, u8)> = None;
        if g.accepting() {
            let n = batcher::decode_admissions(
                g.dec_active.len(),
                g.dec_pending.len(),
                &self.cfg.batch,
            );
            for _ in 0..n {
                let s = g.dec_pending.pop_front().unwrap();
                g.dec_active.push(s);
            }
            admitted = n;
            // Priority-aware preemption (multi-tenant runs only; with no
            // tenant classes every tier is standard and the strict
            // comparison below never fires): when the batch is full and
            // a strictly higher-priority request waits, swap it in for
            // the lowest-priority active decode. The preempted item
            // returns to the pending queue with `tokens_done` preserved
            // (progress is never lost, like the failure-requeue path)
            // and keeps its HBM reservation — its KV stays parked
            // resident until readmission. At most one swap per kick.
            if n == 0
                && !self.cfg.tenants.is_empty()
                && !g.dec_pending.is_empty()
                && !g.dec_active.is_empty()
            {
                let tiers = &self.tenant_tiers;
                let tier_of = |tenant: u8| {
                    tiers
                        .get(tenant as usize)
                        .copied()
                        .unwrap_or(crate::workload::tracespec::TIER_STANDARD)
                };
                // Best pending: lowest tier number, FIFO among ties.
                let (promote_idx, promote_tier) = g
                    .dec_pending
                    .iter()
                    .enumerate()
                    .map(|(i, &s)| (i, tier_of(store.get(s).req.tenant)))
                    .min_by_key(|&(i, t)| (t, i))
                    .unwrap();
                // Victim: highest tier number; ties break to the last
                // slot (deterministic).
                let (victim_idx, victim_tier) = g
                    .dec_active
                    .iter()
                    .enumerate()
                    .map(|(i, &s)| (i, tier_of(store.get(s).req.tenant)))
                    .max_by_key(|&(i, t)| (t, i))
                    .unwrap();
                if promote_tier < victim_tier {
                    let promoted = g.dec_pending.remove(promote_idx).unwrap();
                    let demoted = g.dec_active.swap_remove(victim_idx);
                    g.dec_active.push(promoted);
                    g.dec_pending.push_back(demoted);
                    self.preempted_by_tier[victim_tier as usize] += 1;
                    if self.obs.is_some() {
                        preempted = Some((
                            store.get(demoted).req.id.0,
                            store.get(promoted).req.id.0,
                            victim_tier,
                            promote_tier,
                        ));
                    }
                }
            }
        }
        if self.obs.is_some() {
            // The admitted slots sit at the tail of `dec_active` (the
            // preemption swap only fires when `admitted == 0`).
            for k in 0..admitted {
                let idx = self.gpus[gi].dec_active.len() - admitted + k;
                let s = self.gpus[gi].dec_active[idx];
                let req = self.store.get(s).req.id.0;
                if let Some(o) = self.obs.as_deref_mut() {
                    o.record(crate::obs::ObsEvent::DecodeAdmit { at: self.now, req, gpu: gi });
                }
            }
            if let Some((victim, by, victim_tier, by_tier)) = preempted {
                if let Some(o) = self.obs.as_deref_mut() {
                    o.record(crate::obs::ObsEvent::Preempt {
                        at: self.now,
                        victim,
                        by,
                        gpu: gi,
                        victim_tier,
                        by_tier,
                    });
                }
            }
        }
        let g = &self.gpus[gi];
        if g.dec_active.is_empty() {
            return;
        }
        let batch = g.dec_active.len();
        let ctx = g.mean_ctx(&self.store);
        self.gpus[gi].busy = true;
        let power = self.power.effective(GpuId(gi), self.now);
        let t = self.model_of(gi).decode_step_time(batch, ctx, power);
        self.gpus[gi].dec_step_time = t;
        let epoch = self.gpus[gi].epoch;
        self.events.push(self.now + t, Event::StepDone { gpu: gi, epoch });
        if self.obs.is_some() {
            let node = self.node_of(gi) as u32;
            let at = self.now;
            if let Some(o) = self.obs.as_deref_mut() {
                o.record(crate::obs::ObsEvent::GpuStep {
                    at,
                    gpu: gi,
                    node,
                    until: at + t,
                    role: Role::Decode,
                    reqs: batch as u32,
                    // One token per active request per decode iteration.
                    tokens: batch as u64,
                });
            }
        }
    }

    pub(crate) fn on_decode_step(&mut self, gi: usize, epoch: u64) {
        if self.gpus[gi].epoch != epoch {
            return;
        }
        let step = self.gpus[gi].dec_step_time;
        self.gpus[gi].busy = false;
        let mut ratio_sum = 0.0;
        // Decode steps are the most frequent event in a run; the
        // finished-items buffer is cluster-owned scratch, not a fresh
        // allocation per step.
        let mut finished = std::mem::take(&mut self.scratch_done);
        finished.clear();
        let mut tpot_sample = None;
        {
            let store = &mut self.store;
            let g = &mut self.gpus[gi];
            let mut idx = 0;
            while idx < g.dec_active.len() {
                let st = store.get_mut(g.dec_active[idx]);
                st.tokens_done += 1;
                ratio_sum += step as f64 / st.req.slo.tpot as f64;
                if st.remaining() == 0 {
                    finished.push(g.dec_active.swap_remove(idx));
                } else {
                    idx += 1;
                }
            }
            let n = g.dec_active.len() + finished.len();
            if n > 0 {
                // One TPOT sample per step: the batch-mean SLO ratio.
                tpot_sample = Some(ratio_sum / n as f64);
            }
        }
        if self.policy.is_dynamic() {
            if let Some(ratio) = tpot_sample {
                self.policy.observe_tpot(self.now, ratio);
            }
        }
        let n_finished = finished.len();
        for slot in finished.drain(..) {
            // The slot dies here: take the state out, then settle memory
            // and the completion record from the owned copy.
            let st = self.store.remove(slot);
            if self.mem.active() {
                // Turn the reservation into a prefix-cache block for the
                // request's conversation (or release it outright).
                let bytes = self.kv_bytes_for(gi, &st);
                let conv = self.conv_of.get(&st.req.id.0).map(|c| c.0);
                self.mem.finish(gi, conv, bytes, st.ctx_tokens());
            }
            let now = self.now;
            self.push_record(&st.req, st.prefill_start, st.first_token, now);
            if let Some(o) = self.obs.as_deref_mut() {
                o.record(crate::obs::ObsEvent::Finish {
                    at: now,
                    req: st.req.id.0,
                    gpu: gi,
                    tokens: st.req.output_tokens,
                });
            }
        }
        self.scratch_done = finished;
        if n_finished > 0 {
            self.reindex(gi); // occupancy dropped: update the pick index
            if self.mem.active() {
                self.retry_memory_waiters(gi);
            }
        }
        self.maybe_finish_drain(gi);
        self.kick_decode(gi);
    }

    /// Completions freed (or made evictable) HBM on `gi`: retry work
    /// parked on a failed reservation — orphaned decode items first,
    /// then publishers stalled with their head pushed back. Items that
    /// still do not fit park again; there is no livelock because each
    /// retry is driven by a completion, not a timer.
    fn retry_memory_waiters(&mut self, gi: usize) {
        if !self.orphan_items.is_empty() {
            let node = self.node_of(gi);
            let items = std::mem::take(&mut self.orphan_items);
            for s in items {
                // The original KV source is gone (orphans outlive their
                // producer); the freshly-freed GPU re-sources the fetch.
                self.redispatch_decode(gi, node, None, s);
            }
        }
        let mut k = 0;
        while k < self.prefill_ids.len() {
            let i = self.prefill_ids[k];
            if !self.gpus[i].publish_wait.is_empty() {
                self.try_publish(i);
                self.kick_prefill(i);
            }
            k += 1;
        }
    }
}
