//! PJRT execution engine: loads AOT artifacts and runs prefill / decode.
//!
//! Follows the reference wiring (/opt/xla-example/load_hlo): HLO **text**
//! -> `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute_b`. Hot-path design (EXPERIMENTS.md
//! §Perf): weights are uploaded **once** as device-resident buffers, and
//! the KV caches returned by prefill/decode stay on device — only token
//! ids, positions and logits cross the host boundary per step. Every call
//! passes `[*params, *data_args]` positionally, exactly as `aot.py`
//! lowered them (multi-output modules: PJRT unpacks the root).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::manifest::{Manifest, VariantKind};

/// The flat serving state travelling between prefill and decode —
/// `concat(k_cache, v_cache, logits)` as ONE device-resident buffer, so
/// the decode chain never moves the cache (or the weights) through the
/// host. See aot.py's calling-convention note.
pub struct KvCache {
    pub state: xla::PjRtBuffer,
    /// Batch lanes the cache was produced for (variant batch size).
    pub batch: usize,
}

/// Prefill output for one batch call.
pub struct PrefillOut {
    /// Greedy next token per lane.
    pub tokens: Vec<i64>,
    pub kv: KvCache,
}

/// Decode-step output.
pub struct DecodeOut {
    pub tokens: Vec<i64>,
    pub kv: KvCache,
}

pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    /// Device-resident weights, uploaded once at load.
    params: Vec<xla::PjRtBuffer>,
    prefill: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    extract: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Load manifest + weights and compile every variant executable.
    pub fn load(artifacts: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts)?;
        manifest.validate()?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e}"))?;

        // --- weights ------------------------------------------------
        let wpath = manifest.dir.join(&manifest.weights_file);
        let bytes = std::fs::read(&wpath)
            .with_context(|| format!("reading {}", wpath.display()))?;
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut params = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let slice = &floats[p.offset_elems..p.offset_elems + p.elems()];
            let buf = client
                .buffer_from_host_buffer(slice, &p.shape, None)
                .map_err(|e| anyhow!("upload {}: {e}", p.name))?;
            params.push(buf);
        }

        // --- executables ---------------------------------------------
        let mut prefill = BTreeMap::new();
        let mut decode = BTreeMap::new();
        let mut extract = BTreeMap::new();
        for v in &manifest.variants {
            let path = manifest.dir.join(&v.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e}", v.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", v.file))?;
            match v.kind {
                VariantKind::Prefill => prefill.insert(v.batch, exe),
                VariantKind::Decode => decode.insert(v.batch, exe),
                VariantKind::Extract => extract.insert(v.batch, exe),
            };
        }
        Ok(Engine {
            manifest,
            client,
            params,
            prefill,
            decode,
            extract,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn prefill_batches(&self) -> Vec<usize> {
        self.prefill.keys().copied().collect()
    }

    pub fn decode_batches(&self) -> Vec<usize> {
        self.decode.keys().copied().collect()
    }

    /// Pull logits out of a device state via the extract module and take
    /// the per-lane argmax (the only per-step host download: B x V f32).
    fn read_logits(&self, state: &xla::PjRtBuffer, batch: usize, vocab: usize) -> Result<Vec<i64>> {
        let exe = self
            .extract
            .get(&batch)
            .ok_or_else(|| anyhow!("no extract variant for batch {batch}"))?;
        let logits_buf = execute_single(exe, &[state])?;
        let logits = logits_buf.to_literal_sync().map_err(|e| anyhow!("{e}"))?;
        Self::argmax_rows(&logits, batch, vocab)
    }

    fn argmax_rows(logits: &xla::Literal, rows: usize, cols: usize) -> Result<Vec<i64>> {
        let flat: Vec<f32> = logits.to_vec().map_err(|e| anyhow!("{e}"))?;
        if flat.len() != rows * cols {
            bail!("logits size {} != {rows}x{cols}", flat.len());
        }
        Ok((0..rows)
            .map(|r| {
                let row = &flat[r * cols..(r + 1) * cols];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i64)
                    .unwrap_or(0)
            })
            .collect())
    }

    /// Run prefill on up to `batch` prompts (padded to the variant batch).
    ///
    /// `prompts` are token id sequences; each is right-padded/truncated to
    /// `prefill_seq`. Returns the first generated token per lane plus the
    /// KV caches (lanes beyond `prompts.len()` are padding).
    pub fn prefill(&self, prompts: &[Vec<i64>]) -> Result<PrefillOut> {
        let s = self.manifest.model.prefill_seq;
        let vocab = self.manifest.model.vocab;
        let batch = self
            .manifest
            .pick_batch(VariantKind::Prefill, prompts.len())
            .ok_or_else(|| {
                anyhow!(
                    "no prefill variant fits {} prompts (have {:?})",
                    prompts.len(),
                    self.prefill_batches()
                )
            })?;
        let exe = &self.prefill[&batch];

        let mut tokens = vec![0i32; batch * s];
        let mut lens = vec![1i32; batch];
        for (i, p) in prompts.iter().enumerate() {
            let n = p.len().min(s).max(1);
            for (j, &t) in p.iter().take(n).enumerate() {
                tokens[i * s + j] = t as i32;
            }
            lens[i] = n as i32;
        }
        let tokens_buf = self
            .client
            .buffer_from_host_buffer(&tokens, &[batch, s], None)
            .map_err(|e| anyhow!("{e}"))?;
        let lens_buf = self
            .client
            .buffer_from_host_buffer(&lens, &[batch], None)
            .map_err(|e| anyhow!("{e}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&tokens_buf);
        args.push(&lens_buf);
        let state = execute_single(exe, &args).map_err(|e| anyhow!("prefill: {e}"))?;
        let next = self.read_logits(&state, batch, vocab)?;
        Ok(PrefillOut {
            tokens: next,
            kv: KvCache { state, batch },
        })
    }

    /// One decode step. `tokens`/`pos` must have `kv.batch` lanes (pad
    /// unused lanes with token 0 / their last pos).
    pub fn decode(&self, tokens: &[i64], pos: &[i64], kv: &KvCache) -> Result<DecodeOut> {
        let batch = kv.batch;
        let vocab = self.manifest.model.vocab;
        if tokens.len() != batch || pos.len() != batch {
            bail!("decode lanes {} != cache batch {batch}", tokens.len());
        }
        let exe = self
            .decode
            .get(&batch)
            .ok_or_else(|| anyhow!("no decode variant for batch {batch}"))?;
        let t: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();
        let p: Vec<i32> = pos.iter().map(|&x| x as i32).collect();
        let t_buf = self
            .client
            .buffer_from_host_buffer(&t, &[batch], None)
            .map_err(|e| anyhow!("{e}"))?;
        let p_buf = self
            .client
            .buffer_from_host_buffer(&p, &[batch], None)
            .map_err(|e| anyhow!("{e}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&t_buf);
        args.push(&p_buf);
        args.push(&kv.state);
        let state = execute_single(exe, &args).map_err(|e| anyhow!("decode: {e}"))?;
        let next = self.read_logits(&state, batch, vocab)?;
        Ok(DecodeOut {
            tokens: next,
            kv: KvCache { state, batch },
        })
    }
}

/// Execute on device buffers; the module has exactly one (array) output
/// which stays on device.
fn execute_single(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
) -> Result<xla::PjRtBuffer> {
    let mut out = exe.execute_b(args).map_err(|e| anyhow!("{e}"))?;
    let replica = out.first_mut().ok_or_else(|| anyhow!("no replica outputs"))?;
    if replica.len() != 1 {
        bail!("expected 1 output buffer, got {}", replica.len());
    }
    Ok(replica.pop().unwrap())
}
