//! Study throughput at scale: run the 8-node, high-rate, bursty
//! `scenarios/stress-grid.toml` and report cells/sec plus aggregate
//! simulated events/sec — the "does the DES core keep up when the grid
//! gets big" number the ROADMAP's scenario-diversity goal depends on.
//!
//! `cargo bench --bench study_throughput [-- --json out.json]`
//! `RAPID_BENCH_REQUESTS=300` shrinks the per-cell trace for CI.

use rapid::bench::{json_arg, BenchReport, Timing};
use rapid::scenario::{Scenario, Study};

fn main() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/stress-grid.toml");
    let mut scenario = Scenario::from_toml_file(path).expect("stress-grid scenario");
    if let Some(n) = std::env::var("RAPID_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
    {
        scenario.requests = n;
    }
    let requests = scenario.requests;

    let t0 = std::time::Instant::now();
    let study = Study::new(scenario).run(None).expect("stress-grid study");
    let wall = t0.elapsed().as_secs_f64().max(1e-9);

    let cells = study.cells.len();
    let events: u64 = study
        .cells
        .iter()
        .filter_map(|c| c.result())
        .map(|r| r.sim_events)
        .sum();
    let (passed, total) = study.checks_passed();
    let cells_per_s = cells as f64 / wall;
    let events_per_s = events as f64 / wall;
    println!(
        "study_throughput: {cells} cells x {requests} reqs in {wall:.2}s \
         ({cells_per_s:.2} cells/s, {:.2} M simulated events/s)",
        events_per_s / 1e6
    );
    println!(
        "  [{}] per-cell invariant checks: {passed}/{total} passed",
        if passed == total { "PASS" } else { "FAIL" }
    );

    if let Some(out) = json_arg() {
        let mut report = BenchReport::new("study_throughput");
        let mut t = Timing::single("study/stress_grid", wall * 1e6);
        t.batch = events as usize; // per_sec == simulated events/s
        report.entries.push(t);
        report.meta.insert("cells".into(), cells.to_string());
        report.meta.insert("requests_per_cell".into(), requests.to_string());
        report.meta.insert("cells_per_s".into(), format!("{cells_per_s:.3}"));
        report.meta.insert("checks_passed".into(), passed.to_string());
        report.meta.insert("checks_total".into(), total.to_string());
        report.write(&out).expect("write bench json");
        println!("wrote {out}");
    }
}
