//! Generational slab: stable integer handles for hot-path object storage.
//!
//! The DES moves requests between queues, batches and waiter pools
//! millions of times per run; shuffling owned structs means memcpy
//! traffic proportional to the struct size. A slab stores each object
//! once and hands out a copyable [`SlotId`] — queues then shuffle 8-byte
//! ids instead of whole structs.
//!
//! Freed slots are reused (the free list keeps the slab dense), so a
//! stale id could otherwise silently alias the slot's next occupant —
//! the classic ABA hazard. Every slot carries a generation counter that
//! bumps on free: a stale id's generation no longer matches, and the
//! checked accessors ([`Slab::get`], [`Slab::remove`]) panic instead of
//! returning the wrong object, while [`Slab::try_get`] reports `None`.

/// Handle to one occupied slab slot. `Copy`, 8 bytes, and safe against
/// reuse: the generation must match the slot's current generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SlotId {
    pub index: u32,
    pub gen: u32,
}

/// Generational slab with free-list reuse. See the module docs.
#[derive(Debug, Clone, Default)]
pub struct Slab<T> {
    slots: Vec<Option<T>>,
    gens: Vec<u32>,
    free: Vec<u32>,
}

impl<T> Slab<T> {
    pub fn new() -> Slab<T> {
        Slab { slots: Vec::new(), gens: Vec::new(), free: Vec::new() }
    }

    /// Pre-size for `cap` concurrent occupants (steady-state runs should
    /// never grow the slab after warmup — see the alloc-count test).
    pub fn with_capacity(cap: usize) -> Slab<T> {
        Slab {
            slots: Vec::with_capacity(cap),
            gens: Vec::with_capacity(cap),
            free: Vec::with_capacity(cap),
        }
    }

    /// Occupied slot count.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Store `value`, reusing a freed slot when one exists.
    pub fn insert(&mut self, value: T) -> SlotId {
        if let Some(index) = self.free.pop() {
            let i = index as usize;
            debug_assert!(self.slots[i].is_none());
            self.slots[i] = Some(value);
            SlotId { index, gen: self.gens[i] }
        } else {
            let index = self.slots.len() as u32;
            self.slots.push(Some(value));
            self.gens.push(0);
            SlotId { index, gen: 0 }
        }
    }

    /// Take the value out and retire the id: the slot's generation bumps,
    /// so every outstanding copy of `id` is now stale (and caught).
    ///
    /// # Panics
    /// On a stale or vacant id — using a freed handle is a logic error.
    pub fn remove(&mut self, id: SlotId) -> T {
        let i = id.index as usize;
        assert_eq!(self.gens[i], id.gen, "stale SlotId (ABA): slot reused since this id was issued");
        let v = self.slots[i].take().expect("SlotId points at a vacant slot");
        self.gens[i] = self.gens[i].wrapping_add(1);
        self.free.push(id.index);
        v
    }

    /// # Panics
    /// On a stale or vacant id.
    pub fn get(&self, id: SlotId) -> &T {
        let i = id.index as usize;
        assert_eq!(self.gens[i], id.gen, "stale SlotId (ABA): slot reused since this id was issued");
        self.slots[i].as_ref().expect("SlotId points at a vacant slot")
    }

    /// # Panics
    /// On a stale or vacant id.
    pub fn get_mut(&mut self, id: SlotId) -> &mut T {
        let i = id.index as usize;
        assert_eq!(self.gens[i], id.gen, "stale SlotId (ABA): slot reused since this id was issued");
        self.slots[i].as_mut().expect("SlotId points at a vacant slot")
    }

    /// Non-panicking lookup: `None` for stale or vacant ids.
    pub fn try_get(&self, id: SlotId) -> Option<&T> {
        let i = id.index as usize;
        if i >= self.slots.len() || self.gens[i] != id.gen {
            return None;
        }
        self.slots[i].as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_round_trip() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(10);
        let b = s.insert(20);
        assert_eq!(s.len(), 2);
        assert_eq!(*s.get(a), 10);
        assert_eq!(*s.get_mut(b), 20);
        assert_eq!(s.remove(a), 10);
        assert_eq!(s.len(), 1);
        assert_eq!(*s.get(b), 20);
    }

    #[test]
    fn freed_slots_are_reused_with_new_generation() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(1);
        s.remove(a);
        let b = s.insert(2);
        // Same physical slot, different generation.
        assert_eq!(b.index, a.index);
        assert_ne!(b.gen, a.gen);
        assert_eq!(*s.get(b), 2);
        assert!(s.try_get(a).is_none(), "stale id must not alias the new occupant");
    }

    #[test]
    #[should_panic(expected = "stale SlotId")]
    fn stale_get_panics() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(1);
        s.remove(a);
        s.insert(2);
        s.get(a);
    }

    #[test]
    #[should_panic(expected = "stale SlotId")]
    fn stale_remove_panics() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(1);
        s.remove(a);
        s.insert(2);
        s.remove(a);
    }

    #[test]
    fn aba_property_random_churn() {
        // Property: across an arbitrary insert/remove interleaving, an id
        // freed at any point never reads back a value again — generation
        // checks catch every reuse of its slot.
        let mut s: Slab<u64> = Slab::with_capacity(8);
        let mut live: Vec<(SlotId, u64)> = Vec::new();
        let mut dead: Vec<SlotId> = Vec::new();
        // Deterministic LCG so the test is reproducible.
        let mut x: u64 = 0x9E3779B97F4A7C15;
        let mut rnd = || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            x >> 33
        };
        for step in 0..4000u64 {
            if live.is_empty() || rnd() % 3 != 0 {
                let id = s.insert(step);
                live.push((id, step));
            } else {
                let k = (rnd() as usize) % live.len();
                let (id, v) = live.swap_remove(k);
                assert_eq!(s.remove(id), v);
                dead.push(id);
            }
            // Every live id still reads its own value...
            for &(id, v) in &live {
                assert_eq!(*s.get(id), v);
            }
            // ...and every dead id stays dead forever (no ABA aliasing).
            for &id in &dead {
                assert!(s.try_get(id).is_none());
            }
        }
        assert_eq!(s.len(), live.len());
    }

    #[test]
    fn with_capacity_does_not_grow_below_cap() {
        let mut s: Slab<u32> = Slab::with_capacity(16);
        let ids: Vec<SlotId> = (0..16).map(|i| s.insert(i)).collect();
        for id in ids {
            s.remove(id);
        }
        // Churn inside the capacity envelope reuses slots.
        for i in 0..16 {
            let id = s.insert(i);
            assert!(id.index < 16);
        }
        assert_eq!(s.len(), 16);
    }
}
