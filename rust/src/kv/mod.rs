//! KV-cache transfer machinery (paper §3.2's ring buffer).

pub mod ring;

pub use ring::{KvRing, PublishRejected, RingError};
