//! RAPID: power-aware dynamic reallocation for disaggregated LLM inference.
//!
//! Reproduction of "Power Aware Dynamic Reallocation For Inference"
//! (Jiang et al., 2026). See DESIGN.md for the architecture and the
//! paper-to-repo substitution map.

// Counting allocator (feature `alloc-count`): lets tests assert the DES
// steady state performs zero heap allocations (see util::alloc_count and
// tests/alloc_steady.rs). Off by default — the wrapper adds an atomic
// increment to every allocation.
#[cfg(feature = "alloc-count")]
#[global_allocator]
static ALLOC: util::alloc_count::CountingAlloc = util::alloc_count::CountingAlloc;

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod env;
pub mod experiments;
pub mod fleet;
pub mod kv;
pub mod mem;
pub mod metrics;
pub mod obs;
pub mod power;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scenario;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod sim;
pub mod types;
pub mod util;
pub mod workload;
