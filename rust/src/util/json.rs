//! Minimal JSON parser (offline substitute for `serde_json`).
//!
//! Parses the artifact manifest (`artifacts/manifest.json`) and emits the
//! experiment result files. Supports the full JSON value grammar minus
//! exotic number forms; good enough for machine-generated JSON.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors descriptively (manifest loading).
    pub fn expect(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Shape-vector helper: `[1, 2, 3]` -> `vec![1, 2, 3]`.
    pub fn as_dims(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    /// Pretty-print with two-space indentation. Committed artifacts
    /// (`BENCH_hotpath.json`, bench baselines) stay human-diffable;
    /// `Display` remains the compact wire form.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.pretty_into(&mut out, 0);
        out
    }

    fn pretty_into(&self, out: &mut String, depth: usize) {
        const INDENT: &str = "  ";
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    out.push_str(if i > 0 { ",\n" } else { "\n" });
                    out.push_str(&INDENT.repeat(depth + 1));
                    v.pretty_into(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&INDENT.repeat(depth));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(if i > 0 { ",\n" } else { "\n" });
                    out.push_str(&INDENT.repeat(depth + 1));
                    out.push_str(&escape(k));
                    out.push_str(": ");
                    v.pretty_into(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&INDENT.repeat(depth));
                out.push('}');
            }
            scalar_or_empty => out.push_str(&scalar_or_empty.to_string()),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write!(f, "{}", escape(s)),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", escape(k))?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| self.err(format!("bad number '{text}': {e}")))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_u64(), Some(1));
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn round_trips_through_display() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"s":"line\nbreak","t":true}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn pretty_round_trips_and_indents() {
        let src = r#"{"arr":[1,2.5,"x"],"empty":[],"n":null,"obj":{"k":true},"s":"a\nb"}"#;
        let v = Json::parse(src).unwrap();
        let pretty = v.pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains("\n  \"arr\": ["));
        assert!(pretty.contains("\"empty\": []"));
        assert!(pretty.contains("\n    \"k\": true"));
    }

    #[test]
    fn as_dims_extracts_shapes() {
        let v = Json::parse("[4, 2, 8, 256, 32]").unwrap();
        assert_eq!(v.as_dims(), Some(vec![4, 2, 8, 256, 32]));
        assert_eq!(Json::parse(r#"["x"]"#).unwrap().as_dims(), None);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).unwrap();
            assert_eq!(m.get("format_version").unwrap().as_u64(), Some(2));
            assert!(m.get("variants").unwrap().as_arr().unwrap().len() >= 2);
        }
    }
}
