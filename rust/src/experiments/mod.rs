//! Experiment drivers: one module per paper figure/table.
//!
//! Each driver builds the paper's workload, runs the relevant cluster
//! configurations through the simulator, renders the same rows/series the
//! paper reports, and checks the paper-shape assertions (who wins, by
//! roughly what factor, where crossovers fall) listed in DESIGN.md §6.
//! The `benches/` targets and the `rapid fig*` CLI subcommands both call
//! into here.

pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;

use crate::config::ClusterConfig;
use crate::metrics::RunResult;
use crate::sim::{self, SimOptions};
use crate::types::Slo;
use crate::util::rng::Rng;
use crate::workload::{build_trace, longbench::LongBench, ArrivalProcess, Trace};

/// One shape assertion: description + pass/fail + the measured detail.
#[derive(Debug, Clone)]
pub struct ShapeCheck {
    pub what: String,
    pub pass: bool,
    pub detail: String,
}

impl ShapeCheck {
    pub fn new(what: impl Into<String>, pass: bool, detail: impl Into<String>) -> Self {
        ShapeCheck {
            what: what.into(),
            pass,
            detail: detail.into(),
        }
    }
}

/// Render checks as a PASS/FAIL block.
pub fn render_checks(checks: &[ShapeCheck]) -> String {
    let mut out = String::new();
    for c in checks {
        out.push_str(&format!(
            "  [{}] {} ({})\n",
            if c.pass { "PASS" } else { "FAIL" },
            c.what,
            c.detail
        ));
    }
    out
}

/// Default request count per simulated run. Large enough for stable
/// percentiles, small enough that full sweeps run in seconds.
pub const DEFAULT_REQUESTS: usize = 1200;

/// Worker threads for sweep fan-out: `RAPID_SWEEP_THREADS` overrides;
/// default is the machine's parallelism. `1` forces serial execution
/// (useful for timing baselines — see `benches/sweep_parallel.rs`).
pub fn sweep_threads() -> usize {
    std::env::var("RAPID_SWEEP_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Fan `f` over `items` across worker threads (work-stealing via a
/// shared atomic cursor), preserving input order in the output.
///
/// This is the sweep runner every figure driver, bench and the
/// `rapid sweep` CLI go through: each sweep point is an independent
/// deterministic simulation (seeded RNGs, no shared state), so results
/// are bit-identical to a serial run regardless of thread count.
/// Implemented on `std::thread::scope` — no external dependency.
pub fn parallel_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = sweep_threads().min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let done: std::sync::Mutex<Vec<(usize, R)>> =
        std::sync::Mutex::new(Vec::with_capacity(items.len()));
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                done.lock().unwrap().push((i, r));
            });
        }
    });
    let mut out = done.into_inner().unwrap();
    out.sort_by_key(|&(i, _)| i);
    out.into_iter().map(|(_, r)| r).collect()
}

/// Build a LongBench trace at a node-level rate (QPS across all GPUs).
pub fn longbench_trace(seed: u64, node_qps: f64, n: usize, slo: Slo) -> Trace {
    let mut root = Rng::new(seed);
    let mut ap = ArrivalProcess::poisson(root.fork(1), node_qps);
    let mut sizes = LongBench::new(root.fork(2));
    build_trace(n, &mut ap, &mut sizes, slo)
}

/// Run one configuration over a trace with default sim options.
pub fn run_config(cfg: &ClusterConfig, trace: &Trace) -> RunResult {
    cfg.validate().expect("config invalid");
    sim::run(cfg, trace, &SimOptions::default())
}

/// A point on an attainment-vs-rate curve.
#[derive(Debug, Clone)]
pub struct RatePoint {
    pub qps_per_gpu: f64,
    pub attainment: f64,
    pub goodput_qps: f64,
    pub qps_per_kw: f64,
}

/// Sweep a config across per-GPU request rates (LongBench), fanning the
/// points over worker threads.
pub fn rate_sweep(
    cfg: &ClusterConfig,
    rates_per_gpu: &[f64],
    seed: u64,
    n: usize,
    slo: Slo,
) -> Vec<RatePoint> {
    parallel_map(rates_per_gpu, |&r| {
        let trace = longbench_trace(seed, r * cfg.total_gpus() as f64, n, slo);
        let res = run_config(cfg, &trace);
        RatePoint {
            qps_per_gpu: r,
            attainment: res.attainment(),
            goodput_qps: res.goodput_qps(),
            qps_per_kw: res.qps_per_kw(),
        }
    })
}

/// Sweep many configs x rates in one flat parallel fan-out (used by the
/// multi-curve figure drivers: no barrier between curves, every
/// (config, rate) point is an independent work unit).
pub fn parallel_rate_sweeps(
    configs: Vec<ClusterConfig>,
    rates_per_gpu: &[f64],
    seed: u64,
    n: usize,
    slo: Slo,
) -> Vec<(ClusterConfig, Vec<RatePoint>)> {
    let jobs: Vec<(usize, f64)> = (0..configs.len())
        .flat_map(|ci| rates_per_gpu.iter().map(move |&r| (ci, r)))
        .collect();
    let points = parallel_map(&jobs, |&(ci, r)| {
        let cfg = &configs[ci];
        let trace = longbench_trace(seed, r * cfg.total_gpus() as f64, n, slo);
        let res = run_config(cfg, &trace);
        RatePoint {
            qps_per_gpu: r,
            attainment: res.attainment(),
            goodput_qps: res.goodput_qps(),
            qps_per_kw: res.qps_per_kw(),
        }
    });
    let per_cfg = rates_per_gpu.len();
    configs
        .into_iter()
        .enumerate()
        .map(|(ci, cfg)| {
            let pts = points[ci * per_cfg..(ci + 1) * per_cfg].to_vec();
            (cfg, pts)
        })
        .collect()
}

/// Highest swept rate whose attainment still meets `threshold`
/// (the paper's "sustainable rate at 80% SLO attainment").
pub fn sustainable_rate(points: &[RatePoint], threshold: f64) -> f64 {
    points
        .iter()
        .filter(|p| p.attainment >= threshold)
        .map(|p| p.qps_per_gpu)
        .fold(0.0, f64::max)
}

/// Linear-interpolated rate at which attainment crosses `threshold`
/// (finer than `sustainable_rate` for factor comparisons).
pub fn crossing_rate(points: &[RatePoint], threshold: f64) -> f64 {
    let mut prev: Option<&RatePoint> = None;
    for p in points {
        if let Some(q) = prev {
            if q.attainment >= threshold && p.attainment < threshold {
                let frac = (q.attainment - threshold) / (q.attainment - p.attainment);
                return q.qps_per_gpu + frac * (p.qps_per_gpu - q.qps_per_gpu);
            }
        }
        prev = Some(p);
    }
    sustainable_rate(points, threshold)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(q: f64, a: f64) -> RatePoint {
        RatePoint {
            qps_per_gpu: q,
            attainment: a,
            goodput_qps: 0.0,
            qps_per_kw: 0.0,
        }
    }

    #[test]
    fn sustainable_rate_picks_last_above_threshold() {
        let pts = vec![pt(0.5, 0.99), pt(1.0, 0.92), pt(1.5, 0.70), pt(2.0, 0.30)];
        assert_eq!(sustainable_rate(&pts, 0.8), 1.0);
        assert_eq!(sustainable_rate(&pts, 0.95), 0.5);
        assert_eq!(sustainable_rate(&pts, 0.2), 2.0);
    }

    #[test]
    fn crossing_rate_interpolates() {
        let pts = vec![pt(1.0, 0.9), pt(2.0, 0.7)];
        let x = crossing_rate(&pts, 0.8);
        assert!((x - 1.5).abs() < 1e-9, "x={x}");
    }

    #[test]
    fn longbench_trace_matches_rate() {
        let t = longbench_trace(1, 12.0, 600, Slo::paper_default());
        assert_eq!(t.len(), 600);
        assert!((t.offered_qps() / 12.0 - 1.0).abs() < 0.2);
    }

    #[test]
    fn parallel_map_preserves_order_and_coverage() {
        let items: Vec<u64> = (0..57).collect();
        let out = parallel_map(&items, |&x| x * 3);
        assert_eq!(out, items.iter().map(|&x| x * 3).collect::<Vec<_>>());
        let empty: Vec<u64> = Vec::new();
        assert!(parallel_map(&empty, |&x: &u64| x).is_empty());
        assert_eq!(parallel_map(&[9u64], |&x| x + 1), vec![10]);
    }

    #[test]
    fn parallel_sweep_matches_serial_results() {
        // Determinism across thread counts: each point derives its trace
        // from (seed, rate) alone, so the fan-out must be bit-identical
        // to a serial pass.
        let cfg = crate::config::presets::p4d4(600.0);
        let rates = [0.5, 1.0];
        let par = rate_sweep(&cfg, &rates, 7, 60, Slo::paper_default());
        let ser: Vec<RatePoint> = rates
            .iter()
            .map(|&r| {
                let trace = longbench_trace(7, r * cfg.total_gpus() as f64, 60, Slo::paper_default());
                let res = run_config(&cfg, &trace);
                RatePoint {
                    qps_per_gpu: r,
                    attainment: res.attainment(),
                    goodput_qps: res.goodput_qps(),
                    qps_per_kw: res.qps_per_kw(),
                }
            })
            .collect();
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.qps_per_gpu, b.qps_per_gpu);
            assert_eq!(a.attainment, b.attainment);
            assert_eq!(a.goodput_qps, b.goodput_qps);
        }
    }

    #[test]
    fn parallel_rate_sweeps_groups_by_config() {
        let configs = vec![
            crate::config::presets::p4d4(600.0),
            crate::config::presets::p5d3_600(),
        ];
        let rates = [0.5, 1.0, 1.5];
        let curves = parallel_rate_sweeps(configs, &rates, 3, 40, Slo::paper_default());
        assert_eq!(curves.len(), 2);
        for (_, pts) in &curves {
            assert_eq!(pts.len(), rates.len());
            for (p, &r) in pts.iter().zip(rates.iter()) {
                assert_eq!(p.qps_per_gpu, r);
            }
        }
    }
}
