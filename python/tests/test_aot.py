"""AOT export checks: manifest consistency, weights layout, HLO validity.

These tests exercise the build-time bridge without re-exporting the full
artifact set (slow); they lower one variant and check the manifest logic
against a pre-built artifacts/ directory when present.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_lowering_prefill():
    cfg = M.ModelConfig(
        vocab=64, d_model=32, n_layers=1, n_heads=2, d_ff=48, max_seq=128, prefill_seq=64
    )
    # monkeypatch-free: lower_prefill only uses cfg via closure args
    lowered, example = aot.lower_prefill(cfg, batch=1)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True -> root is a 3-tuple (logits, k, v)
    assert "(f32[1,64]" in text.replace(" ", "")[:20000] or "tuple" in text


def test_hlo_text_lowering_decode():
    cfg = M.ModelConfig(
        vocab=64, d_model=32, n_layers=1, n_heads=2, d_ff=48, max_seq=128, prefill_seq=64
    )
    lowered, example = aot.lower_decode(cfg, batch=2)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # ids must be text-parse friendly (no serialized proto involved)
    assert not text.startswith("\x08")


def test_param_table_offsets_contiguous():
    cfg = M.ModelConfig()
    offset = 0
    for name, shape in cfg.param_specs():
        size = int(np.prod(shape))
        offset += size
    # embed + 4 * (2*d + 4*d*d + 2*d*ff + ff*d) + final_norm + lm_head
    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    expect = v * d + L * (2 * d + 4 * d * d + 3 * d * f) + d + d * v
    assert offset == expect


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts/ not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    @pytest.fixture(autouse=True)
    def _load(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            self.manifest = json.load(f)

    def test_manifest_schema(self):
        m = self.manifest
        assert m["format_version"] == 2
        assert {v["kind"] for v in m["variants"]} == {"prefill", "decode", "extract"}
        for v in m["variants"]:
            assert os.path.exists(os.path.join(ARTIFACTS, v["file"]))
            expected = "logits" if v["kind"] == "extract" else "state"
            assert v["outputs"][0]["name"] == expected
            assert v["state_elems"] > 0

    def test_weights_bin_size(self):
        m = self.manifest
        path = os.path.join(ARTIFACTS, m["weights"]["file"])
        assert os.path.getsize(path) == m["weights"]["total_elems"] * 4

    def test_param_offsets_match_specs(self):
        m = self.manifest
        cfg = M.ModelConfig()
        specs = cfg.param_specs()
        assert [p["name"] for p in m["params"]] == [n for n, _ in specs]
        offset = 0
        for p, (_, shape) in zip(m["params"], specs):
            assert p["offset_elems"] == offset
            assert tuple(p["shape"]) == shape
            offset += int(np.prod(shape))

    def test_weights_reproduce_init(self):
        """weights.bin must be exactly init_params(seed from manifest)."""
        m = self.manifest
        cfg = M.ModelConfig()
        params = M.init_params(cfg, m["seed"])
        raw = np.fromfile(os.path.join(ARTIFACTS, m["weights"]["file"]), dtype="<f4")
        off = 0
        for name, shape in cfg.param_specs()[:3]:  # spot-check first params
            size = int(np.prod(shape))
            np.testing.assert_allclose(
                raw[off : off + size].reshape(shape), params[name], atol=1e-7
            )
            off += size

    def test_variant_batches(self):
        m = self.manifest
        pb = sorted(v["batch"] for v in m["variants"] if v["kind"] == "prefill")
        db = sorted(v["batch"] for v in m["variants"] if v["kind"] == "decode")
        assert pb == sorted(aot.PREFILL_BATCHES)
        assert db == sorted(aot.DECODE_BATCHES)


class TestStatePacking:
    """The flat-state calling convention (aot.py v2) must round-trip."""

    def _cfg(self):
        return M.ModelConfig(
            vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=48,
            max_seq=128, prefill_seq=64,
        )

    def test_state_elems_accounting(self):
        cfg = self._cfg()
        for b in (1, 2, 4):
            n = aot.cache_elems(cfg, b)
            assert n == cfg.n_layers * b * cfg.n_heads * cfg.max_seq * cfg.head_dim
            assert aot.state_elems(cfg, b) == 2 * n + b * cfg.vocab

    def test_pack_unpack_round_trip(self):
        import jax
        cfg = self._cfg()
        b = 2
        key = jax.random.PRNGKey(0)
        shape = aot.cache_shape(cfg, b)
        kc = jax.random.normal(key, shape, jnp.float32)
        vc = jax.random.normal(jax.random.fold_in(key, 1), shape, jnp.float32)
        logits = jax.random.normal(jax.random.fold_in(key, 2), (b, cfg.vocab), jnp.float32)
        state = aot._pack(cfg, b, logits, kc, vc)
        assert state.shape == (aot.state_elems(cfg, b),)
        kc2, vc2 = aot._unpack_caches(cfg, b, state)
        np.testing.assert_array_equal(kc2, kc)
        np.testing.assert_array_equal(vc2, vc)
        # The extract slice is the logits tail.
        tail = state[2 * aot.cache_elems(cfg, b):].reshape(b, cfg.vocab)
        np.testing.assert_array_equal(tail, logits)

    def test_decode_through_state_matches_direct(self):
        """decode lowered through pack/unpack == M.decode directly."""
        import jax
        cfg = self._cfg()
        params = M.init_params(cfg, seed=5)
        b = 1
        tokens = jnp.array([[3] * cfg.prefill_seq], jnp.int32)
        lens = jnp.array([10], jnp.int32)
        logits, kc, vc = M.prefill(cfg, params, tokens, lens)
        state = aot._pack(cfg, b, logits, kc, vc)
        kc2, vc2 = aot._unpack_caches(cfg, b, state)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        d1, k1, v1 = M.decode(cfg, params, tok, lens, kc, vc)
        d2, k2, v2 = M.decode(cfg, params, tok, lens, kc2, vc2)
        np.testing.assert_array_equal(d1, d2)
        np.testing.assert_array_equal(k1, k2)
