//! Central request router (paper §3.2).
//!
//! "A central scheduler process receives incoming requests, routes them
//! to a specific worker, and coordinates inter-stage communication."
//! Routing is least-loaded: prefill by queued prompt tokens (prompt cost
//! is token-proportional), decode by active+pending request count
//! (decode cost is batch-slot-proportional). On heterogeneous fleets
//! every load is first normalized by the worker's SKU throughput
//! (`perf_scale`), so "least loaded" means *soonest drained*, not
//! smallest queue — a part with 2x the prompt rate legitimately holds
//! 2x the backlog. Homogeneous fleets have `perf_scale == 1.0`
//! everywhere, which reduces bit-exactly to the raw comparisons.

use std::cmp::Ordering;

use crate::types::GpuId;

/// Load summary of one candidate worker, as the router sees it.
#[derive(Debug, Clone, Copy)]
pub struct WorkerLoad {
    pub gpu: GpuId,
    /// Node hosting this worker (cross-node KV transfers are slower).
    pub node: usize,
    /// Queued prompt tokens (prefill) — the unit of prefill backlog.
    pub queued_tokens: u64,
    /// Queued + active requests — the unit of decode occupancy.
    pub requests: usize,
    /// Workers mid-drain are not eligible.
    pub accepting: bool,
    /// Relative SKU throughput of this worker (1.0 = the fleet's
    /// reference part): prefill rate for prefill pools, step rate for
    /// decode pools. Loads divide by it before comparison.
    pub perf_scale: f64,
}

impl WorkerLoad {
    /// Throughput-normalized prefill backlog (≈ seconds to drain).
    #[inline]
    fn eff_tokens(&self) -> f64 {
        self.queued_tokens as f64 / self.perf_scale
    }

    /// Throughput-normalized decode occupancy.
    #[inline]
    fn eff_requests(&self) -> f64 {
        self.requests as f64 / self.perf_scale
    }
}

#[inline]
fn prefill_order(a: &WorkerLoad, b: &WorkerLoad) -> Ordering {
    a.eff_tokens()
        .total_cmp(&b.eff_tokens())
        .then(a.requests.cmp(&b.requests))
        .then(a.gpu.0.cmp(&b.gpu.0))
}

#[inline]
fn decode_order(a: &WorkerLoad, b: &WorkerLoad) -> Ordering {
    a.eff_requests()
        .total_cmp(&b.eff_requests())
        .then(a.queued_tokens.cmp(&b.queued_tokens))
        .then(a.gpu.0.cmp(&b.gpu.0))
}

/// Pick the prefill worker with the least (throughput-normalized)
/// queued prompt tokens.
///
/// Called once per arrival/publish on the simulator's hot path — the
/// cluster core reuses one scratch `Vec<WorkerLoad>` across calls so a
/// routing decision allocates nothing.
#[inline]
pub fn pick_prefill(loads: &[WorkerLoad]) -> Option<GpuId> {
    loads
        .iter()
        .filter(|l| l.accepting)
        .min_by(|a, b| prefill_order(a, b))
        .map(|l| l.gpu)
}

/// Pick the decode worker with the fewest (throughput-normalized)
/// resident requests.
#[inline]
pub fn pick_decode(loads: &[WorkerLoad]) -> Option<GpuId> {
    loads
        .iter()
        .filter(|l| l.accepting)
        .min_by(|a, b| decode_order(a, b))
        .map(|l| l.gpu)
}

/// Extra (normalized) resident requests we tolerate on a same-node
/// decode worker before paying a cross-node KV transfer instead
/// (locality bias).
pub const LOCALITY_SLACK_REQS: usize = 4;

/// Pick a decode worker preferring `node` (where the KV cache already
/// lives): take the least-loaded local worker unless a remote worker is
/// more than `LOCALITY_SLACK_REQS` normalized requests lighter.
#[inline]
pub fn pick_decode_prefer_node(loads: &[WorkerLoad], node: usize) -> Option<GpuId> {
    let global = pick_decode(loads)?;
    let global_load = loads
        .iter()
        .find(|l| l.gpu == global)
        .map(WorkerLoad::eff_requests)
        .unwrap_or(0.0);
    let local = loads
        .iter()
        .filter(|l| l.accepting && l.node == node)
        .min_by(|a, b| decode_order(a, b));
    match local {
        Some(l) if l.eff_requests() <= global_load + LOCALITY_SLACK_REQS as f64 => Some(l.gpu),
        _ => Some(global),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(gpu: usize, tokens: u64, reqs: usize, accepting: bool) -> WorkerLoad {
        scaled_load(gpu, tokens, reqs, accepting, 1.0)
    }

    fn scaled_load(
        gpu: usize,
        tokens: u64,
        reqs: usize,
        accepting: bool,
        scale: f64,
    ) -> WorkerLoad {
        WorkerLoad {
            gpu: GpuId(gpu),
            node: gpu / 8,
            queued_tokens: tokens,
            requests: reqs,
            accepting,
            perf_scale: scale,
        }
    }

    #[test]
    fn prefill_prefers_fewest_tokens() {
        let loads = [load(0, 5000, 1, true), load(1, 200, 9, true), load(2, 3000, 0, true)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(1)));
    }

    #[test]
    fn decode_prefers_fewest_requests() {
        let loads = [load(0, 0, 7, true), load(1, 0, 2, true), load(2, 0, 4, true)];
        assert_eq!(pick_decode(&loads), Some(GpuId(1)));
    }

    #[test]
    fn draining_workers_skipped() {
        let loads = [load(0, 0, 0, false), load(1, 9000, 30, true)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(1)));
        assert_eq!(pick_decode(&loads), Some(GpuId(1)));
        let none = [load(0, 0, 0, false)];
        assert_eq!(pick_prefill(&none), None);
    }

    #[test]
    fn ties_break_by_gpu_id_for_determinism() {
        let loads = [load(2, 100, 1, true), load(0, 100, 1, true), load(1, 100, 1, true)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(0)));
        assert_eq!(pick_decode(&loads), Some(GpuId(0)));
    }

    #[test]
    fn empty_pool_is_none() {
        assert_eq!(pick_prefill(&[]), None);
        assert_eq!(pick_decode(&[]), None);
        assert_eq!(pick_decode_prefer_node(&[], 0), None);
    }

    #[test]
    fn locality_keeps_kv_on_node_when_loads_close() {
        // gpu 1 is on node 0 (local, slightly busier), gpu 9 on node 1.
        let loads = [load(1, 0, 3, true), load(9, 0, 1, true)];
        assert_eq!(pick_decode_prefer_node(&loads, 0), Some(GpuId(1)));
        // Without a local candidate it falls back to the global pick.
        assert_eq!(pick_decode_prefer_node(&loads, 2), Some(GpuId(9)));
    }

    #[test]
    fn locality_yields_to_big_imbalance() {
        // Local worker is far busier than the remote one: pay the link.
        let loads = [load(1, 0, 30, true), load(9, 0, 1, true)];
        assert_eq!(pick_decode_prefer_node(&loads, 0), Some(GpuId(9)));
    }

    #[test]
    fn locality_skips_draining_local_workers() {
        let loads = [load(1, 0, 0, false), load(9, 0, 5, true)];
        assert_eq!(pick_decode_prefer_node(&loads, 0), Some(GpuId(9)));
    }

    // ------------------------------------------------------------------
    // heterogeneous (SKU-normalized) routing
    // ------------------------------------------------------------------

    #[test]
    fn prefill_normalizes_backlog_by_throughput() {
        // GPU 0 is 2x faster and holds 2x - 1 tokens: it drains sooner,
        // so it wins despite the raw queue being deeper.
        let loads = [scaled_load(0, 3999, 0, true, 2.0), scaled_load(1, 2000, 0, true, 1.0)];
        assert_eq!(pick_prefill(&loads), Some(GpuId(0)));
        // At exactly 2x the tokens the drain times tie: requests, then
        // gpu id break it deterministically.
        let tie = [scaled_load(0, 4000, 1, true, 2.0), scaled_load(1, 2000, 1, true, 1.0)];
        assert_eq!(pick_prefill(&tie), Some(GpuId(0)));
        // A slow part with a small queue still loses to a fast empty one.
        let slow = [scaled_load(0, 0, 0, true, 2.0), scaled_load(1, 100, 0, true, 0.5)];
        assert_eq!(pick_prefill(&slow), Some(GpuId(0)));
    }

    #[test]
    fn decode_normalizes_occupancy_by_throughput() {
        // 6 requests on a 2x part == 3 normalized < 4 on the 1x part.
        let loads = [scaled_load(0, 0, 6, true, 2.0), scaled_load(1, 0, 4, true, 1.0)];
        assert_eq!(pick_decode(&loads), Some(GpuId(0)));
    }

    #[test]
    fn perf_scale_exact_ties_break_by_requests_then_id() {
        // Normalized prefill backlogs tie exactly (4000/2.0 == 2000/1.0):
        // the raw request count breaks the tie...
        let deep_fast = scaled_load(5, 4000, 3, true, 2.0);
        let shallow_slow = scaled_load(1, 2000, 1, true, 1.0);
        assert_eq!(pick_prefill(&[deep_fast, shallow_slow]), Some(GpuId(1)));
        // ...and with requests tied too, the lowest GPU id wins, so the
        // pick is deterministic regardless of scale combinations.
        let full_tie = scaled_load(7, 4000, 1, true, 2.0);
        assert_eq!(pick_prefill(&[full_tie, shallow_slow]), Some(GpuId(1)));
        assert_eq!(pick_prefill(&[shallow_slow, full_tie]), Some(GpuId(1)), "order-free");
        // Decode: normalized occupancy ties (8/2.0 == 4/1.0) break by
        // queued tokens, then id.
        let busy_fast = scaled_load(2, 5, 8, true, 2.0);
        let calm_slow = scaled_load(4, 0, 4, true, 1.0);
        assert_eq!(pick_decode(&[busy_fast, calm_slow]), Some(GpuId(4)));
        let token_tie = scaled_load(6, 0, 8, true, 2.0);
        assert_eq!(pick_decode(&[token_tie, calm_slow]), Some(GpuId(4)), "id breaks full tie");
    }

    #[test]
    fn perf_scale_tiny_and_fractional_scales_stay_finite_and_ordered() {
        // A severely derated part (scale 0.25) holding a small queue
        // still loses to a healthy empty one; zero-queue entries compare
        // equal across any scale (0/s == 0.0) and fall to the id tie.
        let derated = scaled_load(3, 100, 0, true, 0.25);
        let healthy = scaled_load(5, 0, 0, true, 1.0);
        assert_eq!(pick_prefill(&[derated, healthy]), Some(GpuId(5)));
        let idle_a = scaled_load(9, 0, 0, true, 0.25);
        let idle_b = scaled_load(4, 0, 0, true, 2.0);
        assert_eq!(pick_prefill(&[idle_a, idle_b]), Some(GpuId(4)));
    }

    #[test]
    fn locality_slack_compares_normalized_loads() {
        // Local worker (node 0) is a slow part: 6 raw / 0.5 = 12
        // normalized, more than slack above the remote's 1 — pay the hop.
        let loads = [scaled_load(1, 0, 6, true, 0.5), scaled_load(9, 0, 1, true, 1.0)];
        assert_eq!(pick_decode_prefer_node(&loads, 0), Some(GpuId(9)));
        // A fast local part with the same raw queue stays local:
        // 6 / 2.0 = 3 normalized <= 1 + 4 slack.
        let fast = [scaled_load(1, 0, 6, true, 2.0), scaled_load(9, 0, 1, true, 1.0)];
        assert_eq!(pick_decode_prefer_node(&fast, 0), Some(GpuId(1)));
    }
}
