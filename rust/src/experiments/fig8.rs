//! Fig 8: static vs dynamic RAPID on the two-phase Sonnet workload
//! (1000 prefill-heavy 8K/128 then 1000 decode-heavy 500/500, TPOT SLO
//! tightening 40 ms -> 20 ms). Expected ordering (paper §5.2):
//!
//!   4P4D-600W, 5P3D-600W            — worst (static uniform)
//!   4P-750W/4D-450W ≈ 4P4D-DynPower — power alone can't fix phase 2
//!   DynGPU-600W                     — better (GPUs follow the phases)
//!   DynGPU-DynPower (full RAPID)    — best overall
//!
//! Plus the headline: RAPID ~2x the static uniform attainment at peak.

use crate::config::{presets, ClusterConfig};
use crate::experiments::ShapeCheck;
use crate::metrics::RunResult;
use crate::scenario::{Axis, Scenario, Study, WorkloadSpec};

pub struct Fig8 {
    pub qps_per_gpu: f64,
    pub rows: Vec<(ClusterConfig, RunResult)>,
}

fn configs() -> Vec<ClusterConfig> {
    vec![
        presets::p4d4(600.0),
        presets::p5d3_600(),
        presets::p4_750_d4_450(),
        presets::dyn_power_600(),
        presets::dyn_gpu_600(),
        presets::rapid_600(),
    ]
}

/// Six config cells over the mixed two-phase trace at one rate.
///
/// The paper runs this figure at its testbed's peak-load point; the
/// substrate-equivalent default is `MixedPhasesSpec::default().rate_qps`.
pub fn scenario(seed: u64, qps_per_gpu: f64, requests_per_phase: usize) -> Scenario {
    Scenario::new("fig8", presets::p4d4(600.0))
        .seed(seed)
        .requests(2 * requests_per_phase)
        .workload(WorkloadSpec::MixedPhases)
        .rate(qps_per_gpu)
        .axis(Axis::Config(configs()))
}

pub fn run(seed: u64, qps_per_gpu: f64, requests_per_phase: usize) -> Fig8 {
    let study = Study::new(scenario(seed, qps_per_gpu, requests_per_phase))
        .run(None)
        .expect("fig8 scenario");
    let rows = study
        .cells
        .into_iter()
        .map(|c| {
            let cfg = c.config.clone();
            (cfg, c.into_result().expect("sim cell"))
        })
        .collect();
    Fig8 { qps_per_gpu, rows }
}

impl Fig8 {
    fn attainment(&self, name: &str) -> f64 {
        self.rows
            .iter()
            .find(|(c, _)| c.name == name)
            .map(|(_, r)| r.attainment())
            .unwrap_or(0.0)
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "SLO attainment, mixed Sonnet workload @{} QPS/GPU\n",
            self.qps_per_gpu
        );
        for (cfg, res) in &self.rows {
            out.push_str(&format!(
                "  {:<18} attainment={:>5.1}%  goodput={:>6.2} qps  qps/kW={:.3}\n",
                cfg.name,
                res.attainment() * 100.0,
                res.goodput_qps(),
                res.qps_per_kw()
            ));
        }
        out
    }

    pub fn checks(&self) -> Vec<ShapeCheck> {
        let uniform = self.attainment("4P4D-600W");
        let p5d3 = self.attainment("5P3D-600W");
        let static_nu = self.attainment("4P-750W/4D-450W");
        let dyn_power = self.attainment("4P4D-DynPower");
        let dyn_gpu = self.attainment("DynGPU-600W");
        let rapid = self.attainment("DynGPU-DynPower");
        vec![
            ShapeCheck::new(
                "full RAPID (DynGPU-DynPower) is best overall",
                rapid >= dyn_gpu - 0.02
                    && rapid > dyn_power
                    && rapid > static_nu
                    && rapid > uniform
                    && rapid > p5d3,
                format!(
                    "rapid={rapid:.2} dyngpu={dyn_gpu:.2} dynpower={dyn_power:.2} \
                     static-nu={static_nu:.2} uniform={uniform:.2} 5p3d={p5d3:.2}"
                ),
            ),
            ShapeCheck::new(
                "DynGPU beats power-only schemes on the phase-shifting trace",
                dyn_gpu > dyn_power && dyn_gpu > static_nu,
                format!("dyngpu={dyn_gpu:.2} dynpower={dyn_power:.2} static-nu={static_nu:.2}"),
            ),
            ShapeCheck::new(
                "DynPower converges to ~the static non-uniform result",
                (dyn_power - static_nu).abs() < 0.15,
                format!("dynpower={dyn_power:.2} static-nu={static_nu:.2}"),
            ),
            ShapeCheck::new(
                "static uniform disaggregation is worst",
                uniform <= dyn_gpu && uniform <= rapid,
                format!("uniform={uniform:.2}"),
            ),
            ShapeCheck::new(
                "headline: RAPID ~2x static uniform attainment at peak load",
                rapid >= 1.5 * uniform || rapid - uniform > 0.3,
                format!("{rapid:.2} vs {uniform:.2} = {:.2}x", rapid / uniform.max(0.01)),
            ),
        ]
    }
}
