//! Fig 5(a)+(b): SLO attainment vs request rate, static configs
//!
//! `cargo bench --bench fig5_slo` regenerates the figure's rows/series and
//! validates the paper-shape assertions (DESIGN.md §6). Absolute numbers
//! differ from the paper (simulated substrate); shapes must hold.

fn main() {
    let n: usize = std::env::var("RAPID_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000);
    let t0 = std::time::Instant::now();
    let fa = rapid::experiments::fig5::run(false, 42, n);
    println!("{}", fa.render());
    let mut checks = fa.checks();
    let fb = rapid::experiments::fig5::run(true, 42, n);
    println!("{}", fb.render());
    checks.extend(fb.checks());
    println!("{}", rapid::experiments::render_checks(&checks));
    rapid::bench::finish_figure_bench("fig5_slo", t0, &checks);
}
