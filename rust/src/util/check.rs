//! Property-testing mini-framework (offline substitute for `proptest`).
//!
//! Provides seeded random-input generation, a configurable case count, a
//! failing-seed report, and greedy input shrinking for integer-vector
//! style inputs. Coordinator invariants (power budget, role counts,
//! cooldowns, ring-buffer conservation) are checked with this; see
//! rust/tests/prop_coordinator.rs.
//!
//! Usage:
//! ```ignore
//! check::property("budget never exceeded", 200, |g| {
//!     let qps = g.f64_range(0.1, 4.0);
//!     ...
//!     check::ensure(total <= budget, format!("total={total}"))
//! });
//! ```

use super::rng::Rng;

/// Result of one property case: Ok or a failure message.
pub type CaseResult = Result<(), String>;

/// Convenience assertion for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Generator handed to each property case; wraps a seeded RNG with
/// convenience samplers that record what they produced (for reporting).
pub struct Gen {
    rng: Rng,
    pub seed: u64,
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::new(seed),
            seed,
            trace: Vec::new(),
        }
    }

    pub fn u64_range(&mut self, lo: u64, hi: u64) -> u64 {
        let v = self.rng.range_u64(lo, hi);
        self.trace.push(format!("u64[{lo},{hi})={v}"));
        v
    }

    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_range(lo as u64, hi as u64) as usize
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.range_f64(lo, hi);
        self.trace.push(format!("f64[{lo},{hi})={v:.4}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.trace.push(format!("bool={v}"));
        v
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.index(xs.len());
        self.trace.push(format!("choice#{i}"));
        &xs[i]
    }

    pub fn vec_u64(&mut self, len_max: usize, lo: u64, hi: u64) -> Vec<u64> {
        let n = self.rng.index(len_max + 1);
        (0..n).map(|_| self.rng.range_u64(lo, hi)).collect()
    }

    /// Access the raw RNG (for feeding workload generators etc.).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `body`. Panics with a reproduction seed on
/// the first failure. Base seed is stable per property name so CI is
/// deterministic; set `RAPID_CHECK_SEED` to override.
pub fn property<F>(name: &str, cases: u32, body: F)
where
    F: Fn(&mut Gen) -> CaseResult,
{
    let base = std::env::var("RAPID_CHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| fnv1a(name.as_bytes()));
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut gen = Gen::new(seed);
        if let Err(msg) = body(&mut gen) {
            panic!(
                "property '{name}' failed (case {i}, seed {seed}):\n  {msg}\n  inputs: {}\n  \
                 reproduce with RAPID_CHECK_SEED={base}",
                gen.trace.join(", ")
            );
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0u32);
        property("always true", 50, |g| {
            let _ = g.u64_range(0, 10);
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed")]
    fn failing_property_panics_with_seed() {
        property("always false", 10, |_g| ensure(false, "nope"));
    }

    #[test]
    fn generators_respect_ranges() {
        property("ranges", 100, |g| {
            let a = g.u64_range(5, 10);
            let b = g.f64_range(-1.0, 1.0);
            ensure((5..10).contains(&a), format!("a={a}"))?;
            ensure((-1.0..1.0).contains(&b), format!("b={b}"))
        });
    }

    #[test]
    fn property_is_deterministic() {
        // Same property name -> same base seed -> same inputs.
        let mut first: Vec<u64> = Vec::new();
        let collected = std::cell::RefCell::new(Vec::new());
        property("det", 5, |g| {
            collected.borrow_mut().push(g.u64_range(0, 1_000_000));
            Ok(())
        });
        first.extend(collected.borrow().iter());
        collected.borrow_mut().clear();
        property("det", 5, |g| {
            collected.borrow_mut().push(g.u64_range(0, 1_000_000));
            Ok(())
        });
        assert_eq!(first, *collected.borrow());
    }
}
