//! Kilo-node DES scale tests (DESIGN.md §13).
//!
//! * **Backend equivalence**: the calendar event queue must reproduce
//!   the plain binary heap's `RunResult` bit-for-bit on every shipped
//!   seed config (`RAPID_EVENTQ=heap` selects the old backend).
//! * **Kilo-node end-to-end**: `configs/kilo-node.toml` (128 nodes,
//!   1024 GPUs) runs to completion, conserves every request through a
//!   mid-run failure, and is deterministic. In debug builds every
//!   router pick along the way is additionally checked against the
//!   linear-scan reference by the `Cluster::pick_*` debug assertions,
//!   so this doubles as a cluster-level index-equivalence test.
//! * **Kilo-grid scenario**: `scenarios/kilo-grid.toml` loads and its
//!   single 1024-GPU cell runs under the Study API.

use rapid::env::EnvProfile;
use rapid::scenario::{Scenario, Study};
use rapid::sim::{self, SimOptions};
use rapid::types::Slo;
use rapid::util::rng::Rng;
use rapid::workload::{build_trace, sonnet::Sonnet, ArrivalProcess};

#[path = "support/mod.rs"]
mod support;
use support::{assert_bit_identical, shipped_config};

fn trace(n: usize, qps: f64, input: u32, output: u32) -> rapid::workload::Trace {
    let mut ap = ArrivalProcess::poisson(Rng::new(91), qps);
    let mut sizes = Sonnet::new(Rng::new(92), input, output);
    build_trace(n, &mut ap, &mut sizes, Slo::paper_default())
}

/// One #[test] for all three configs so the `RAPID_EVENTQ` toggles are
/// serialized. A concurrently-running test that happens to construct a
/// queue mid-toggle would pick up the heap backend — which is exactly
/// the backend this test proves result-identical, so the race is benign.
#[test]
fn calendar_and_heap_backends_are_bit_identical_on_shipped_configs() {
    for (file, n, qps, input, output) in [
        ("rapid-600.toml", 250, 16.0, 2500, 48),
        ("two-node-4p4d.toml", 250, 20.0, 2500, 48),
        ("hetero-4p4d.toml", 250, 14.0, 2500, 48),
    ] {
        let cfg = shipped_config(file);
        let t = trace(n, qps, input, output);
        std::env::set_var("RAPID_EVENTQ", "heap");
        let heap = sim::run(&cfg, &t, &SimOptions::default());
        std::env::remove_var("RAPID_EVENTQ");
        let calendar = sim::run(&cfg, &t, &SimOptions::default());
        assert_bit_identical(&heap, &calendar);
        assert!(heap.sim_events > 0, "{file}: run must do work");
    }
}

#[test]
fn kilo_node_runs_end_to_end_and_conserves_requests_through_churn() {
    let mut cfg = shipped_config("kilo-node.toml");
    assert_eq!(cfg.n_nodes, 128);
    assert_eq!(cfg.total_gpus(), 1024);
    // A failure + recovery mid-run so the indexed role lists, the power
    // books and the orphan paths all see churn at kilo scale.
    cfg.env = EnvProfile::parse_compact("fail:1:17+recover:2:17").unwrap();
    cfg.validate().unwrap();
    let n = 400;
    let t = trace(n, 512.0, 1200, 48);
    let r = sim::run(&cfg, &t, &SimOptions::default());
    assert_eq!(r.records.len(), n, "kilo-node run must lose zero requests");
    let unique: std::collections::HashSet<u64> = r.records.iter().map(|x| x.id.0).collect();
    assert_eq!(unique.len(), n, "no request recorded twice");
    // Deterministic at scale (and under the calendar queue).
    let r2 = sim::run(&cfg, &t, &SimOptions::default());
    assert_bit_identical(&r, &r2);
}

#[test]
fn kilo_grid_scenario_smokes() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/kilo-grid.toml");
    let mut s = Scenario::from_toml_file(path).unwrap();
    assert_eq!(s.n_cells(), 1, "one big cell: scale, not coverage");
    s.requests = 40;
    let study = Study::new(s).run(Some(1)).unwrap();
    let cell = &study.cells[0];
    assert_eq!(cell.config.n_nodes, 128);
    assert_eq!(cell.config.total_gpus(), 1024);
    let (passed, total) = study.checks_passed();
    assert_eq!(passed, total, "per-cell invariant checks must pass");
}
