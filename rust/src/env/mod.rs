//! Environment subsystem: timed operational disturbances for the DES
//! (DESIGN.md §12).
//!
//! RAPID's claim is that *dynamic* reallocation sustains goodput "within
//! strict power caps" — which is only testable when the caps, the fleet
//! and the thermal envelopes actually move mid-run. This module owns the
//! disturbance model:
//!
//! * [`EnvEvent`] — one timed disturbance: a cluster/node budget step
//!   (grid curtailment), a GPU failure/recovery (fleet churn), or a
//!   thermal derate/clear (a GPU's max-power ceiling temporarily drops);
//! * [`EnvProfile`] — a declarative timeline: hand-written events plus
//!   two deterministic generators (periodic [`Curtailment`] windows and
//!   a Poisson [`FaultProcess`] with MTTR), expanded seed-reproducibly
//!   by [`EnvProfile::expand`];
//! * TOML surfaces — `[env]` tables in config files
//!   ([`EnvProfile::from_doc`]) and the compact `env` scenario axis
//!   grammar ([`EnvProfile::parse_compact`], e.g.
//!   `"curtail:30:0.5:0.75:10"` or `"fail:8:5+recover:20:5"`).
//!
//! The cluster core injects expanded events into its event heap
//! (`sim::event::Event::Env`); the power manager sheds/derates inside
//! SKU floors and ceilings; every [`crate::cluster::policy::Policy`]
//! sees the disturbance through `on_env_event` so dynamic controllers
//! can rebalance immediately instead of waiting for a latency window to
//! fill. With an empty profile nothing is injected and the simulation
//! is bit-identical to the pre-env code.
//!
//! ```
//! use rapid::env::EnvProfile;
//!
//! let p = EnvProfile::parse_compact("curtail:30:0.5:0.75:10").unwrap();
//! assert!(!p.is_empty());
//! assert!(EnvProfile::parse_compact("none").unwrap().is_empty());
//! ```

use std::fmt;

use crate::config::toml::Document;
use crate::types::{Micros, Watts, SECOND};
use crate::util::rng::Rng;

/// Which budget level a [`EnvDisturbance::CapChange`] steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CapScope {
    /// The facility-level cluster budget.
    Cluster,
    /// One node's budget.
    Node(usize),
}

/// One kind of operational disturbance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EnvDisturbance {
    /// The budget at `scope` steps to `watts` (curtailment drop or
    /// restore). Decreases shed caps immediately; increases free
    /// headroom but raise nothing by themselves.
    CapChange { scope: CapScope, watts: Watts },
    /// GPU `gpu` (cluster-global index) leaves the fleet: queued and
    /// in-flight prefill work re-runs elsewhere, decode items re-fetch
    /// their KV over the ring, the GPU stops drawing and counting
    /// toward any budget.
    GpuFail { gpu: usize },
    /// The failed GPU rejoins at its cap floor; power re-spreads.
    GpuRecover { gpu: usize },
    /// Thermal derating: the GPU's max-power ceiling drops to `max_w`
    /// (clamped into its SKU envelope) until cleared.
    ThermalThrottle { gpu: usize, max_w: Watts },
    /// Thermal derating ends: the rated ceiling is restored (the cap
    /// itself stays put until a policy raises it).
    ThermalClear { gpu: usize },
}

impl fmt::Display for EnvDisturbance {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EnvDisturbance::CapChange { scope: CapScope::Cluster, watts } => {
                write!(f, "cluster-cap -> {watts:.0} W")
            }
            EnvDisturbance::CapChange { scope: CapScope::Node(nd), watts } => {
                write!(f, "node{nd}-cap -> {watts:.0} W")
            }
            EnvDisturbance::GpuFail { gpu } => write!(f, "gpu{gpu} FAIL"),
            EnvDisturbance::GpuRecover { gpu } => write!(f, "gpu{gpu} RECOVER"),
            EnvDisturbance::ThermalThrottle { gpu, max_w } => {
                write!(f, "gpu{gpu} throttle -> {max_w:.0} W")
            }
            EnvDisturbance::ThermalClear { gpu } => write!(f, "gpu{gpu} thermal clear"),
        }
    }
}

/// A disturbance pinned to a simulation time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnvEvent {
    pub at: Micros,
    pub what: EnvDisturbance,
}

/// Periodic grid-curtailment windows: starting at `start`, every
/// `period` the cluster budget drops to `budget_frac` of its base value
/// for `duty * period`, then restores.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Curtailment {
    pub period: Micros,
    /// Fraction of each period spent curtailed, in (0, 1).
    pub duty: f64,
    /// Cluster budget multiplier while curtailed, in (0, 1].
    pub budget_frac: f64,
    /// Offset of the first window.
    pub start: Micros,
}

/// Fleet-level Poisson failure process: failures arrive with mean
/// inter-arrival `mtbf`, each takes a uniformly-drawn currently-up GPU
/// down for `mttr`. Fully determined by `seed`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultProcess {
    pub mtbf: Micros,
    pub mttr: Micros,
    pub seed: u64,
    /// Hard cap on injected failures (runaway guard).
    pub max_failures: usize,
}

/// A declarative disturbance timeline: explicit events plus generators.
/// The default (empty) profile injects nothing.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnvProfile {
    /// Hand-written events (absolute times).
    pub events: Vec<EnvEvent>,
    pub curtailment: Option<Curtailment>,
    pub faults: Option<FaultProcess>,
}

fn parse_secs(s: &str) -> Result<Micros, String> {
    s.trim()
        .parse::<f64>()
        .ok()
        .filter(|v| *v >= 0.0 && v.is_finite())
        .map(|v| (v * SECOND as f64) as Micros)
        .ok_or_else(|| format!("'{s}' is not a non-negative time in seconds"))
}

fn parse_watts(s: &str) -> Result<Watts, String> {
    s.trim()
        .parse::<f64>()
        .ok()
        .filter(|v| *v > 0.0 && v.is_finite())
        .ok_or_else(|| format!("'{s}' is not a positive wattage"))
}

fn parse_index(s: &str, what: &str) -> Result<usize, String> {
    s.trim()
        .parse::<usize>()
        .map_err(|_| format!("'{s}' is not a valid {what} index"))
}

/// Entry kinds an `[env]` table's string arrays accept.
const EVENT_KINDS: &[&str] = &["cluster_cap", "node_cap", "fail", "recover", "throttle", "clear"];

fn parse_event(kind: &str, entry: &str) -> Result<EnvEvent, String> {
    let err = |msg: &str| format!("env.{kind} entry '{entry}': {msg}");
    let parts: Vec<&str> = entry.split(':').collect();
    let need = |n: usize, shape: &str| {
        if parts.len() == n {
            Ok(())
        } else {
            Err(err(&format!("expected '{shape}'")))
        }
    };
    let what = match kind {
        "cluster_cap" => {
            need(2, "t_s:watts")?;
            EnvDisturbance::CapChange {
                scope: CapScope::Cluster,
                watts: parse_watts(parts[1]).map_err(|e| err(&e))?,
            }
        }
        "node_cap" => {
            need(3, "t_s:node:watts")?;
            EnvDisturbance::CapChange {
                scope: CapScope::Node(parse_index(parts[1], "node").map_err(|e| err(&e))?),
                watts: parse_watts(parts[2]).map_err(|e| err(&e))?,
            }
        }
        "fail" => {
            need(2, "t_s:gpu")?;
            EnvDisturbance::GpuFail { gpu: parse_index(parts[1], "gpu").map_err(|e| err(&e))? }
        }
        "recover" => {
            need(2, "t_s:gpu")?;
            EnvDisturbance::GpuRecover { gpu: parse_index(parts[1], "gpu").map_err(|e| err(&e))? }
        }
        "throttle" => {
            need(3, "t_s:gpu:max_w")?;
            EnvDisturbance::ThermalThrottle {
                gpu: parse_index(parts[1], "gpu").map_err(|e| err(&e))?,
                max_w: parse_watts(parts[2]).map_err(|e| err(&e))?,
            }
        }
        "clear" => {
            need(2, "t_s:gpu")?;
            EnvDisturbance::ThermalClear { gpu: parse_index(parts[1], "gpu").map_err(|e| err(&e))? }
        }
        other => return Err(format!("unknown env event kind '{other}'")),
    };
    Ok(EnvEvent { at: parse_secs(parts[0]).map_err(|e| err(&e))?, what })
}

impl EnvProfile {
    /// Nothing to inject?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.curtailment.is_none() && self.faults.is_none()
    }

    /// Parse the `[env]` tables of a config document. Returns `None`
    /// when the document declares no environment at all.
    pub fn from_doc(doc: &Document) -> Result<Option<EnvProfile>, String> {
        let mut p = EnvProfile::default();
        let mut any = false;
        for &kind in EVENT_KINDS {
            let path = format!("env.{kind}");
            match doc.get(&path) {
                None => {}
                Some(v) => {
                    let values = v
                        .as_array()
                        .ok_or_else(|| format!("{path} must be an array of event strings"))?;
                    any = true;
                    for v in values {
                        let s = v
                            .as_str()
                            .ok_or_else(|| format!("{path} entries must be strings"))?;
                        p.events.push(parse_event(kind, s)?);
                    }
                }
            }
        }
        let secs = |v: f64| (v.max(0.0) * SECOND as f64) as Micros;
        if let Some(period_s) = doc.get_f64("env.curtailment.period_s") {
            any = true;
            p.curtailment = Some(Curtailment {
                period: secs(period_s),
                duty: doc.get_f64("env.curtailment.duty").unwrap_or(0.5),
                budget_frac: doc.get_f64("env.curtailment.budget_frac").unwrap_or(0.75),
                start: secs(doc.get_f64("env.curtailment.start_s").unwrap_or(0.0)),
            });
        } else if doc.keys_under("env.curtailment").next().is_some() {
            return Err("env.curtailment needs period_s".into());
        }
        match (doc.get_f64("env.faults.mtbf_s"), doc.get_f64("env.faults.mttr_s")) {
            (Some(mtbf_s), Some(mttr_s)) => {
                any = true;
                p.faults = Some(FaultProcess {
                    mtbf: secs(mtbf_s),
                    mttr: secs(mttr_s),
                    seed: doc.get_i64("env.faults.seed").unwrap_or(1) as u64,
                    max_failures: doc.get_i64("env.faults.max_failures").unwrap_or(32) as usize,
                });
            }
            (None, None) => {
                if doc.keys_under("env.faults").next().is_some() {
                    return Err("env.faults needs mtbf_s and mttr_s".into());
                }
            }
            _ => return Err("env.faults needs both mtbf_s and mttr_s".into()),
        }
        Ok(if any { Some(p) } else { None })
    }

    /// Parse the compact one-string grammar the scenario `env` axis
    /// uses: `+`-joined atoms, e.g.
    /// `"curtail:30:0.5:0.75:10"`, `"faults:25:10:7:4"`,
    /// `"fail:8:5+recover:20:5"`, `"cap:10:4000"`,
    /// `"throttle:12:1:500+clear:40:1"`, or `"none"`.
    pub fn parse_compact(s: &str) -> Result<EnvProfile, String> {
        let s = s.trim();
        let mut p = EnvProfile::default();
        if s.is_empty() || s == "none" {
            return Ok(p);
        }
        for atom in s.split('+') {
            let atom = atom.trim();
            let parts: Vec<&str> = atom.split(':').collect();
            let rest = parts[1..].join(":");
            match (parts[0], parts.len()) {
                ("cap", 3) => p.events.push(parse_event("cluster_cap", &rest)?),
                ("nodecap", 4) => p.events.push(parse_event("node_cap", &rest)?),
                ("fail", 3) => p.events.push(parse_event("fail", &rest)?),
                ("recover", 3) => p.events.push(parse_event("recover", &rest)?),
                ("throttle", 4) => p.events.push(parse_event("throttle", &rest)?),
                ("clear", 3) => p.events.push(parse_event("clear", &rest)?),
                ("curtail", 4) | ("curtail", 5) => {
                    if p.curtailment.is_some() {
                        return Err(format!("duplicate curtail atom '{atom}'"));
                    }
                    p.curtailment = Some(Curtailment {
                        period: parse_secs(parts[1])?,
                        duty: parts[2]
                            .parse::<f64>()
                            .map_err(|_| format!("curtail duty '{}' must be a number", parts[2]))?,
                        budget_frac: parts[3].parse::<f64>().map_err(|_| {
                            format!("curtail budget_frac '{}' must be a number", parts[3])
                        })?,
                        start: if parts.len() == 5 { parse_secs(parts[4])? } else { 0 },
                    });
                }
                ("faults", 4) | ("faults", 5) => {
                    if p.faults.is_some() {
                        return Err(format!("duplicate faults atom '{atom}'"));
                    }
                    p.faults = Some(FaultProcess {
                        mtbf: parse_secs(parts[1])?,
                        mttr: parse_secs(parts[2])?,
                        seed: parts[3]
                            .parse::<u64>()
                            .map_err(|_| format!("faults seed '{}' must be an integer", parts[3]))?,
                        max_failures: if parts.len() == 5 {
                            parts[4].parse::<usize>().map_err(|_| {
                                format!("faults max '{}' must be an integer", parts[4])
                            })?
                        } else {
                            32
                        },
                    });
                }
                _ => {
                    return Err(format!(
                        "unknown env atom '{atom}' (none | cap:t:w | nodecap:t:n:w | fail:t:g | \
                         recover:t:g | throttle:t:g:w | clear:t:g | curtail:period:duty:frac[:start] | \
                         faults:mtbf:mttr:seed[:max])"
                    ));
                }
            }
        }
        Ok(p)
    }

    /// Structural validation against a cluster's shape and budgets.
    /// `cluster_floor` / `node_floor` are the summed per-GPU cap floors
    /// a curtailed budget must still be able to host (only enforced
    /// when the config enforces budgets at all).
    pub fn validate(
        &self,
        total_gpus: usize,
        n_nodes: usize,
        enforce: bool,
        cluster_floor: Watts,
        node_floor: Watts,
        cluster_budget: Watts,
    ) -> Result<(), String> {
        let err = |m: String| Err(m);
        for e in &self.events {
            match e.what {
                EnvDisturbance::CapChange { scope: CapScope::Cluster, watts } => {
                    if enforce && watts + 1e-6 < cluster_floor {
                        return err(format!(
                            "env cluster cap {watts} W below the fleet cap floor {cluster_floor} W"
                        ));
                    }
                }
                EnvDisturbance::CapChange { scope: CapScope::Node(nd), watts } => {
                    if nd >= n_nodes {
                        return err(format!(
                            "env node cap names node {nd} but n_nodes is {n_nodes}"
                        ));
                    }
                    if enforce && watts + 1e-6 < node_floor {
                        return err(format!(
                            "env node cap {watts} W below the node cap floor {node_floor} W"
                        ));
                    }
                }
                EnvDisturbance::GpuFail { gpu }
                | EnvDisturbance::GpuRecover { gpu }
                | EnvDisturbance::ThermalThrottle { gpu, .. }
                | EnvDisturbance::ThermalClear { gpu } => {
                    if gpu >= total_gpus {
                        return err(format!(
                            "env event names gpu {gpu} but the cluster has {total_gpus} GPUs"
                        ));
                    }
                }
            }
        }
        if let Some(c) = &self.curtailment {
            if c.period == 0 {
                return err("curtailment period must be > 0".into());
            }
            if !(0.0..1.0).contains(&c.duty) || c.duty <= 0.0 {
                return err(format!("curtailment duty {} must be in (0, 1)", c.duty));
            }
            if !(0.0..=1.0).contains(&c.budget_frac) || c.budget_frac <= 0.0 {
                return err(format!(
                    "curtailment budget_frac {} must be in (0, 1]",
                    c.budget_frac
                ));
            }
            if enforce && c.budget_frac * cluster_budget + 1e-6 < cluster_floor {
                return err(format!(
                    "curtailed budget {:.0} W below the fleet cap floor {cluster_floor} W",
                    c.budget_frac * cluster_budget
                ));
            }
        }
        if let Some(fp) = &self.faults {
            if fp.mtbf == 0 || fp.mttr == 0 {
                return err("fault mtbf_s and mttr_s must be > 0".into());
            }
            if fp.max_failures == 0 {
                return err("fault max_failures must be >= 1".into());
            }
        }
        Ok(())
    }

    /// Expand the profile into a sorted concrete timeline for a cluster
    /// of `total_gpus` GPUs whose base cluster budget is
    /// `base_cluster_budget`, out to `horizon`. Deterministic: same
    /// profile + same arguments → the same timeline, always.
    pub fn expand(
        &self,
        total_gpus: usize,
        base_cluster_budget: Watts,
        horizon: Micros,
    ) -> Vec<EnvEvent> {
        let mut out = self.events.clone();
        if let Some(c) = &self.curtailment {
            let mut t = c.start;
            while t < horizon {
                out.push(EnvEvent {
                    at: t,
                    what: EnvDisturbance::CapChange {
                        scope: CapScope::Cluster,
                        watts: base_cluster_budget * c.budget_frac,
                    },
                });
                out.push(EnvEvent {
                    at: t + (c.duty * c.period as f64) as Micros,
                    what: EnvDisturbance::CapChange {
                        scope: CapScope::Cluster,
                        watts: base_cluster_budget,
                    },
                });
                t = t.saturating_add(c.period);
            }
        }
        if let Some(fp) = &self.faults {
            // Salted so a fault stream never aliases a workload stream
            // built from the same user seed.
            let mut rng = Rng::new(fp.seed ^ 0x00E5_7FA1_7000);
            let mut down_until = vec![0u64; total_gpus];
            let mut t: Micros = 0;
            let mut injected = 0usize;
            while injected < fp.max_failures {
                t = t.saturating_add((rng.exponential(1.0) * fp.mtbf as f64) as Micros);
                if t >= horizon {
                    break;
                }
                // Linear probe from a uniform pick to the next currently-up
                // GPU keeps the draw deterministic and non-overlapping.
                let pick = rng.index(total_gpus);
                let gpu = (0..total_gpus)
                    .map(|k| (pick + k) % total_gpus)
                    .find(|&g| down_until[g] <= t);
                let Some(gpu) = gpu else { continue };
                let back = t.saturating_add(fp.mttr);
                down_until[gpu] = back;
                out.push(EnvEvent { at: t, what: EnvDisturbance::GpuFail { gpu } });
                out.push(EnvEvent { at: back, what: EnvDisturbance::GpuRecover { gpu } });
                injected += 1;
            }
        }
        out.sort_by_key(|e| e.at);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SECOND;

    #[test]
    fn empty_profile_expands_to_nothing() {
        let p = EnvProfile::default();
        assert!(p.is_empty());
        assert!(p.expand(8, 4800.0, 600 * SECOND).is_empty());
        assert_eq!(EnvProfile::parse_compact("none").unwrap(), p);
        assert_eq!(EnvProfile::parse_compact("  ").unwrap(), p);
    }

    #[test]
    fn compact_atoms_parse() {
        let p = EnvProfile::parse_compact("cap:10:4000+fail:8:5+recover:20:5").unwrap();
        assert_eq!(p.events.len(), 3);
        assert_eq!(
            p.events[0],
            EnvEvent {
                at: 10 * SECOND,
                what: EnvDisturbance::CapChange { scope: CapScope::Cluster, watts: 4000.0 }
            }
        );
        assert_eq!(p.events[1].what, EnvDisturbance::GpuFail { gpu: 5 });
        assert_eq!(p.events[2].at, 20 * SECOND);
        let c = EnvProfile::parse_compact("curtail:30:0.5:0.75:10").unwrap();
        let cur = c.curtailment.unwrap();
        assert_eq!(cur.period, 30 * SECOND);
        assert_eq!(cur.start, 10 * SECOND);
        assert_eq!(cur.duty, 0.5);
        let f = EnvProfile::parse_compact("faults:25:10:7:4").unwrap();
        let fp = f.faults.unwrap();
        assert_eq!(fp.mtbf, 25 * SECOND);
        assert_eq!(fp.mttr, 10 * SECOND);
        assert_eq!(fp.seed, 7);
        assert_eq!(fp.max_failures, 4);
        let t = EnvProfile::parse_compact("throttle:12:1:500+clear:40:1").unwrap();
        assert_eq!(
            t.events[0].what,
            EnvDisturbance::ThermalThrottle { gpu: 1, max_w: 500.0 }
        );
        assert_eq!(t.events[1].what, EnvDisturbance::ThermalClear { gpu: 1 });
    }

    #[test]
    fn bad_compact_atoms_rejected() {
        assert!(EnvProfile::parse_compact("warp:9").is_err());
        assert!(EnvProfile::parse_compact("cap:10").is_err());
        assert!(EnvProfile::parse_compact("fail:x:3").is_err());
        assert!(EnvProfile::parse_compact("cap:10:-5").is_err());
        assert!(EnvProfile::parse_compact("curtail:30:0.5:0.75+curtail:10:0.5:0.9").is_err());
        assert!(EnvProfile::parse_compact("faults:25:10:7+faults:1:1:1").is_err());
    }

    #[test]
    fn from_doc_parses_env_tables() {
        let doc = Document::parse(
            r#"
[env]
cluster_cap = ["10:4000", "25:4800"]
node_cap = ["12:0:1800"]
fail = ["15:3"]
recover = ["35:3"]
throttle = ["12.5:1:500"]
clear = ["40:1"]
[env.curtailment]
period_s = 60
duty = 0.4
budget_frac = 0.8
start_s = 5
[env.faults]
mtbf_s = 120
mttr_s = 20
seed = 9
max_failures = 3
"#,
        )
        .unwrap();
        let p = EnvProfile::from_doc(&doc).unwrap().unwrap();
        assert_eq!(p.events.len(), 6);
        assert!(p.events.iter().any(|e| e.at == 12_500_000
            && e.what == EnvDisturbance::ThermalThrottle { gpu: 1, max_w: 500.0 }));
        let c = p.curtailment.unwrap();
        assert_eq!(c.period, 60 * SECOND);
        assert_eq!(c.start, 5 * SECOND);
        let f = p.faults.unwrap();
        assert_eq!((f.mtbf, f.mttr, f.seed, f.max_failures), (120 * SECOND, 20 * SECOND, 9, 3));
        // No [env] at all -> None.
        assert!(EnvProfile::from_doc(&Document::parse("x = 1").unwrap())
            .unwrap()
            .is_none());
        // Half-declared generators are rejected.
        let half = Document::parse("[env.faults]\nmtbf_s = 10").unwrap();
        assert!(EnvProfile::from_doc(&half).is_err());
        let half = Document::parse("[env.curtailment]\nduty = 0.5").unwrap();
        assert!(EnvProfile::from_doc(&half).is_err());
        let bad = Document::parse("[env]\nfail = [\"oops\"]").unwrap();
        assert!(EnvProfile::from_doc(&bad).is_err());
    }

    #[test]
    fn curtailment_expands_to_alternating_steps() {
        let p = EnvProfile {
            curtailment: Some(Curtailment {
                period: 30 * SECOND,
                duty: 0.5,
                budget_frac: 0.75,
                start: 10 * SECOND,
            }),
            ..Default::default()
        };
        let tl = p.expand(8, 4800.0, 75 * SECOND);
        // Windows at 10s and 40s and 70s (70 < 75), each with a restore.
        assert_eq!(tl.len(), 6);
        let caps: Vec<(Micros, f64)> = tl
            .iter()
            .map(|e| match e.what {
                EnvDisturbance::CapChange { watts, .. } => (e.at, watts),
                _ => panic!("unexpected {e:?}"),
            })
            .collect();
        assert_eq!(caps[0], (10 * SECOND, 3600.0));
        assert_eq!(caps[1], (25 * SECOND, 4800.0));
        assert_eq!(caps[2], (40 * SECOND, 3600.0));
        assert_eq!(caps[3], (55 * SECOND, 4800.0));
        assert_eq!(caps[4], (70 * SECOND, 3600.0));
        assert_eq!(caps[5], (85 * SECOND, 4800.0));
    }

    #[test]
    fn fault_process_is_deterministic_and_non_overlapping() {
        let p = EnvProfile {
            faults: Some(FaultProcess {
                mtbf: 20 * SECOND,
                mttr: 15 * SECOND,
                seed: 7,
                max_failures: 6,
            }),
            ..Default::default()
        };
        let a = p.expand(8, 4800.0, 300 * SECOND);
        let b = p.expand(8, 4800.0, 300 * SECOND);
        assert_eq!(a, b, "same seed must expand to the same timeline");
        assert!(!a.is_empty());
        // Every failure pairs with a recovery mttr later, and no GPU
        // fails again while still down.
        let mut down: Vec<Option<Micros>> = vec![None; 8];
        for e in &a {
            match e.what {
                EnvDisturbance::GpuFail { gpu } => {
                    assert!(down[gpu].is_none() || down[gpu].unwrap() <= e.at, "{e:?}");
                    down[gpu] = Some(e.at + 15 * SECOND);
                }
                EnvDisturbance::GpuRecover { gpu } => {
                    assert_eq!(down[gpu], Some(e.at), "recovery must be mttr after failure");
                }
                _ => panic!("unexpected {e:?}"),
            }
        }
        // A different seed gives a different stream.
        let mut p2 = p.clone();
        p2.faults.as_mut().unwrap().seed = 8;
        assert_ne!(p2.expand(8, 4800.0, 300 * SECOND), a);
    }

    #[test]
    fn validation_catches_bad_shapes() {
        let gpu_oob = EnvProfile::parse_compact("fail:1:9").unwrap();
        assert!(gpu_oob.validate(8, 1, true, 3200.0, 3200.0, 4800.0).is_err());
        assert!(gpu_oob.validate(16, 2, true, 3200.0, 1600.0, 9600.0).is_ok());
        let cap_low = EnvProfile::parse_compact("cap:10:3000").unwrap();
        assert!(cap_low.validate(8, 1, true, 3200.0, 3200.0, 4800.0).is_err());
        // Unenforced budgets skip the floor comparison.
        assert!(cap_low.validate(8, 1, false, 3200.0, 3200.0, 4800.0).is_ok());
        let node_oob = EnvProfile::parse_compact("nodecap:10:2:2400").unwrap();
        assert!(node_oob.validate(16, 2, true, 6400.0, 3200.0, 9600.0).is_err());
        let deep = EnvProfile::parse_compact("curtail:30:0.5:0.5").unwrap();
        assert!(deep.validate(8, 1, true, 3200.0, 3200.0, 4800.0).is_err(), "2400 W < floor");
        let ok = EnvProfile::parse_compact("curtail:30:0.5:0.75").unwrap();
        ok.validate(8, 1, true, 3200.0, 3200.0, 4800.0).unwrap();
        let bad_duty = EnvProfile::parse_compact("curtail:30:1.5:0.75").unwrap();
        assert!(bad_duty.validate(8, 1, true, 3200.0, 3200.0, 4800.0).is_err());
    }
}
